//! Static timing analysis: longest combinational path over the mapped,
//! placed and routed design; the clock period and Fig-2 computation
//! latencies derive from it.
//!
//! Arrival-time propagation in topological order over the instance graph
//! (flops/macro-sequentials are cut points), with per-net wire delay =
//! routed net length * the library's ps/um constant, split across sinks.

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::library::CellLibrary;
use super::placement::build_pin_nets;
use super::routing::RoutingResult;
use super::synthesis::MappedDesign;

/// Static-timing result for one placed-and-routed design.
#[derive(Debug, Clone)]
pub struct TimingReport {
    /// Longest register-to-register (or port-to-port) path, ps.
    pub critical_path_ps: f64,
    /// Suggested clock period (critical path + setup/clock margin), ps.
    pub clock_period_ps: f64,
    /// Achievable frequency, MHz.
    pub fmax_mhz: f64,
    /// Instance names along the critical path (driver order).
    pub critical_path: Vec<String>,
    /// Levels of logic on the critical path.
    pub depth: usize,
}

/// Fraction of the period reserved for clock skew + setup (Innovus default
/// margins are of this order).
const MARGIN: f64 = 1.10;

/// Static timing analysis: arrival-time propagation in topological order;
/// fails on combinational cycles or net-bookkeeping mismatches.
pub fn analyze(d: &MappedDesign, lib: &CellLibrary, routing: &RoutingResult) -> Result<TimingReport> {
    // Per-net wire delay: routed length * ps/um.
    let nets = build_pin_nets(d);
    let mut net_delay: HashMap<usize, f64> = HashMap::new(); // keyed by net id? build mapping
    // build_pin_nets drops net ids; rebuild id mapping here.
    let mut net_ids: Vec<usize> = Vec::new();
    {
        let mut pin_nets: Vec<Vec<usize>> = vec![Vec::new(); d.num_nets];
        for (ii, inst) in d.instances.iter().enumerate() {
            for &n in inst.inputs.iter().chain(inst.outputs.iter()) {
                let v = &mut pin_nets[n];
                if v.last() != Some(&ii) {
                    v.push(ii);
                }
            }
        }
        for (nid, v) in pin_nets.iter().enumerate() {
            if v.len() >= 2 && v.len() <= super::placement::GLOBAL_NET_PINS {
                net_ids.push(nid);
            }
        }
    }
    if net_ids.len() != nets.len() || nets.len() != routing.net_hpwl_um.len() {
        bail!("net bookkeeping mismatch");
    }
    for (k, &nid) in net_ids.iter().enumerate() {
        // Direct-route (HPWL) wire delay: critical nets get priority routes.
        net_delay.insert(nid, routing.net_hpwl_um[k] * lib.tech.wire_delay_ps_per_um);
    }

    // driver instance per net.
    let mut driver: Vec<Option<usize>> = vec![None; d.num_nets];
    for (ii, inst) in d.instances.iter().enumerate() {
        for &o in &inst.outputs {
            driver[o] = Some(ii);
        }
    }

    // Topological order over combinational instances (seq = cut points).
    let mut state = vec![0u8; d.instances.len()];
    let mut order: Vec<usize> = Vec::with_capacity(d.instances.len());
    for start in 0..d.instances.len() {
        if state[start] != 0 || d.instances[start].is_seq {
            continue;
        }
        let mut stack = vec![(start, 0usize)];
        state[start] = 1;
        while let Some(&mut (ii, ref mut child)) = stack.last_mut() {
            let inst = &d.instances[ii];
            if *child < inst.inputs.len() {
                let net = inst.inputs[*child];
                *child += 1;
                if let Some(pg) = driver[net] {
                    if !d.instances[pg].is_seq {
                        match state[pg] {
                            0 => {
                                state[pg] = 1;
                                stack.push((pg, 0));
                            }
                            1 => bail!("combinational cycle at {}", d.instances[pg].name),
                            _ => {}
                        }
                    }
                }
            } else {
                state[ii] = 2;
                order.push(ii);
                stack.pop();
            }
        }
    }

    // Arrival times per net: seq outputs and primary inputs start at 0
    // (clk-to-q folded into the flop cell delay at the sink side).
    let mut arrival: Vec<f64> = vec![0.0; d.num_nets];
    let mut from: Vec<Option<usize>> = vec![None; d.num_nets];
    for &ii in &order {
        let inst = &d.instances[ii];
        let cell = d.cell_of(inst);
        let in_arr = inst
            .inputs
            .iter()
            .map(|&n| arrival[n])
            .fold(0.0f64, f64::max);
        let worst_in = inst
            .inputs
            .iter()
            .max_by(|&&a, &&b| arrival[a].partial_cmp(&arrival[b]).unwrap())
            .copied();
        for &o in &inst.outputs {
            let wire = net_delay.get(&o).copied().unwrap_or(0.0);
            let t = in_arr + cell.delay_ps + wire;
            if t > arrival[o] {
                arrival[o] = t;
                from[o] = worst_in;
            }
        }
        let _ = worst_in;
    }

    // Critical endpoint: max arrival at any sequential input or primary out.
    let mut crit_net = None;
    let mut crit = 0.0f64;
    for inst in &d.instances {
        if inst.is_seq {
            for &n in &inst.inputs {
                if arrival[n] > crit {
                    crit = arrival[n];
                    crit_net = Some(n);
                }
            }
        }
    }
    for &n in &d.primary_outputs {
        if arrival[n] > crit {
            crit = arrival[n];
            crit_net = Some(n);
        }
    }
    // Add one flop delay (clk-to-q + setup) to the path.
    let flop_overhead = lib.std_cell(crate::rtl::GateKind::Dff).delay_ps;
    let critical_path_ps = crit + flop_overhead;

    // Trace the path back for the report.
    let mut path = Vec::new();
    let mut cur = crit_net;
    let mut depth = 0;
    while let Some(n) = cur {
        if let Some(di) = driver[n] {
            path.push(d.instances[di].name.clone());
            depth += 1;
            if path.len() > 10_000 {
                break;
            }
        }
        cur = from[n];
    }
    path.reverse();

    let clock_period_ps = critical_path_ps * MARGIN;
    Ok(TimingReport {
        critical_path_ps,
        clock_period_ps,
        fmax_mhz: 1.0e6 / clock_period_ps,
        critical_path: path,
        depth,
    })
}

/// Computation latency for one inference sample (Fig 2): cycles * period.
pub fn computation_latency_ns(period_ps: f64, t_r: i32) -> f64 {
    let cycles = crate::rtl::column::cycles_per_sample(t_r) as f64;
    cycles * period_ps / 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ColumnConfig;
    use crate::eda::cells::{asap7, freepdk45};
    use crate::eda::placement::{place, PlaceOpts};
    use crate::eda::routing::route;
    use crate::eda::synthesis::synthesize;
    use crate::rtl::generate_column;

    fn timed(lib: &CellLibrary, p: usize) -> TimingReport {
        let cfg = ColumnConfig::new("StaTest", "synthetic", p, 2);
        let rtl = generate_column(&cfg).unwrap();
        let d = synthesize(&rtl.netlist, lib);
        let pl = place(&d, &PlaceOpts::default());
        let r = route(&d, &pl);
        analyze(&d, lib, &r).unwrap()
    }

    #[test]
    fn critical_path_positive_and_traced() {
        let t = timed(&asap7(), 6);
        assert!(t.critical_path_ps > 50.0);
        assert!(!t.critical_path.is_empty());
        assert!(t.fmax_mhz > 1.0);
    }

    #[test]
    fn period_has_margin() {
        let t = timed(&asap7(), 6);
        assert!(t.clock_period_ps > t.critical_path_ps);
    }

    #[test]
    fn bigger_column_is_slower() {
        let small = timed(&asap7(), 4);
        let large = timed(&asap7(), 16);
        assert!(large.critical_path_ps > small.critical_path_ps);
    }

    #[test]
    fn node_45nm_slower_than_7nm() {
        let a = timed(&asap7(), 6);
        let f = timed(&freepdk45(), 6);
        assert!(f.critical_path_ps > 1.5 * a.critical_path_ps);
    }

    #[test]
    fn latency_formula() {
        assert!((computation_latency_ns(1000.0, 32) - 34.0).abs() < 1e-9);
    }
}
