//! EDA-flow substrate (the Cadence Genus/Innovus substitute): cell
//! libraries, logic synthesis with TNN7 macro mapping, simulated-annealing
//! placement, global routing, static timing and power analysis, and the
//! flow orchestrator with per-stage runtime measurement.
//!
//! See DESIGN.md's substitution table for the argument of why this
//! preserves the paper's claims: every Table-III/IV/Fig-2/Fig-3 quantity is
//! computed by the same causal mechanism (cell counts x per-cell constants,
//! placement wall-clock x instance count, critical path x wire delay), with
//! per-cell constants calibrated to published PDK data.

pub mod cache;
pub mod cells;
pub mod flow;
pub mod library;
pub mod placement;
pub mod power;
pub mod routing;
pub mod sta;
pub mod synthesis;

pub use cache::{FlowCache, FLOW_CODE_VERSION};
pub use cells::{all_libraries, asap7, freepdk45, tnn7};
pub use flow::{
    run_flow, run_flow_cached, run_flow_on_rtl, FlowCampaign, FlowJob, FlowOpts, FlowReport,
    StageRuntimes,
};
pub use library::{Cell, CellLibrary, TechParams};
pub use placement::{place, PlaceOpts, Placement};
pub use power::PowerReport;
pub use routing::{route, RoutingResult};
pub use sta::TimingReport;
pub use synthesis::{synthesize, MappedDesign, SynthStats};
