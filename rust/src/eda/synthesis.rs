//! Logic synthesis (the Genus substitute): generic-gate optimization
//! (constant folding, common-subexpression elimination, dead-code removal)
//! followed by technology mapping onto a cell library, including TNN7 macro
//! mapping.
//!
//! The TNN7 flow reproduces the ref-[8] synthesis-speedup mechanism
//! faithfully: recognized TNN hierarchy groups (synapse units, neuron adder
//! trees, WTA, input interface) are collapsed into pre-optimized hard
//! macros FIRST, so the expensive gate-level optimization only runs over
//! the small residual fabric — that is where the ~3x synthesis-runtime
//! advantage comes from, and our measured stage runtimes show the same
//! shape (Fig 3 bench).

use std::collections::HashMap;
use std::time::Instant;

use crate::rtl::netlist::{Gate, GateKind, NetId, Netlist};

use super::library::{Cell, CellLibrary};

/// One mapped instance (std cell or macro).
#[derive(Debug, Clone)]
pub struct MappedInstance {
    /// Hierarchical instance name.
    pub name: String,
    /// Index into `MappedDesign::cells`.
    pub cell: usize,
    /// Input net ids.
    pub inputs: Vec<NetId>,
    /// Output net ids.
    pub outputs: Vec<NetId>,
    /// True for flops and macros absorbing sequential gates (STA cut
    /// points).
    pub is_seq: bool,
    /// True for TNN7 macro instances.
    pub is_macro: bool,
}

/// Synthesis statistics (reported by the benches and the CLI).
#[derive(Debug, Clone, Default)]
pub struct SynthStats {
    /// Generic gates entering synthesis.
    pub gates_in: usize,
    /// Gates remaining after generic optimization.
    pub gates_optimized: usize,
    /// Gates removed by constant folding / aliasing.
    pub const_folded: usize,
    /// Gates merged by structural hashing (CSE).
    pub cse_merged: usize,
    /// Gates removed as dead code.
    pub dce_removed: usize,
    /// Std-cell instances after mapping.
    pub std_instances: usize,
    /// Macro instances after mapping (0 for pure std-cell libraries).
    pub macro_instances: usize,
    /// Measured synthesis wall-clock (s) — the Fig-3 "synth" component.
    pub runtime_s: f64,
}

/// A technology-mapped design: the synthesis output consumed by placement,
/// routing, STA and power analysis.
#[derive(Debug, Clone)]
pub struct MappedDesign {
    /// Design (netlist) name.
    pub name: String,
    /// Library the design was mapped onto.
    pub library: String,
    /// Distinct cells used (instances index into this table).
    pub cells: Vec<Cell>,
    /// All mapped instances.
    pub instances: Vec<MappedInstance>,
    /// Net count carried over from the source netlist.
    pub num_nets: usize,
    /// Primary-input net ids.
    pub primary_inputs: Vec<NetId>,
    /// Primary-output net ids.
    pub primary_outputs: Vec<NetId>,
    /// Optimization/mapping statistics.
    pub stats: SynthStats,
}

impl MappedDesign {
    /// Total cell area (um^2).
    pub fn area_um2(&self) -> f64 {
        self.instances.iter().map(|i| self.cells[i.cell].area_um2).sum()
    }
    /// Total cell leakage (nW).
    pub fn leakage_nw(&self) -> f64 {
        self.instances.iter().map(|i| self.cells[i.cell].leakage_nw).sum()
    }
    /// The cell an instance is mapped onto.
    pub fn cell_of(&self, inst: &MappedInstance) -> &Cell {
        &self.cells[inst.cell]
    }
}

// ---------------------------------------------------------------------------
// Generic-gate optimization
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq)]
enum NetVal {
    Unknown,
    Const(bool),
    Alias(NetId),
}

fn resolve(vals: &[NetVal], mut n: NetId) -> (NetId, Option<bool>) {
    loop {
        match vals[n] {
            NetVal::Const(b) => return (n, Some(b)),
            NetVal::Alias(a) => n = a,
            NetVal::Unknown => return (n, None),
        }
    }
}

/// One round of constant folding + aliasing. Returns #gates simplified.
fn const_fold_round(n: &mut Netlist, vals: &mut Vec<NetVal>) -> usize {
    let mut changed = 0;
    for gi in 0..n.gates.len() {
        let g = &n.gates[gi];
        if g.kind == GateKind::Dff {
            continue;
        }
        // Resolve inputs through the alias map.
        let resolved: Vec<(NetId, Option<bool>)> =
            g.inputs.iter().map(|&i| resolve(vals, i)).collect();
        let out = g.output;
        if matches!(vals[out], NetVal::Const(_) | NetVal::Alias(_)) {
            continue; // already simplified
        }
        let set_const = |vals: &mut Vec<NetVal>, b: bool| {
            vals[out] = NetVal::Const(b);
        };
        let set_alias = |vals: &mut Vec<NetVal>, a: NetId| {
            if a != out {
                vals[out] = NetVal::Alias(a);
            }
        };
        let before = vals[out];
        match g.kind {
            GateKind::Const0 => set_const(vals, false),
            GateKind::Const1 => set_const(vals, true),
            GateKind::Buf => match resolved[0] {
                (_, Some(b)) => set_const(vals, b),
                (a, None) => set_alias(vals, a),
            },
            GateKind::Inv => {
                if let (_, Some(b)) = resolved[0] {
                    set_const(vals, !b);
                }
            }
            GateKind::And2 | GateKind::Nand2 => {
                let inv = g.kind == GateKind::Nand2;
                match (resolved[0], resolved[1]) {
                    ((_, Some(a)), (_, Some(b))) => set_const(vals, (a & b) ^ inv),
                    ((_, Some(false)), _) | (_, (_, Some(false))) => set_const(vals, inv),
                    ((a, None), (_, Some(true))) | ((_, Some(true)), (a, None)) => {
                        if inv {
                            n.gates[gi] = Gate {
                                kind: GateKind::Inv,
                                name: n.gates[gi].name.clone(),
                                inputs: vec![a],
                                output: out,
                            };
                            changed += 1;
                            continue;
                        } else {
                            set_alias(vals, a);
                        }
                    }
                    _ => {}
                }
            }
            GateKind::Or2 | GateKind::Nor2 => {
                let inv = g.kind == GateKind::Nor2;
                match (resolved[0], resolved[1]) {
                    ((_, Some(a)), (_, Some(b))) => set_const(vals, (a | b) ^ inv),
                    ((_, Some(true)), _) | (_, (_, Some(true))) => set_const(vals, true ^ inv),
                    ((a, None), (_, Some(false))) | ((_, Some(false)), (a, None)) => {
                        if inv {
                            n.gates[gi] = Gate {
                                kind: GateKind::Inv,
                                name: n.gates[gi].name.clone(),
                                inputs: vec![a],
                                output: out,
                            };
                            changed += 1;
                            continue;
                        } else {
                            set_alias(vals, a);
                        }
                    }
                    _ => {}
                }
            }
            GateKind::Xor2 | GateKind::Xnor2 => {
                let inv = g.kind == GateKind::Xnor2;
                match (resolved[0], resolved[1]) {
                    ((_, Some(a)), (_, Some(b))) => set_const(vals, (a ^ b) ^ inv),
                    ((a, None), (_, Some(c))) | ((_, Some(c)), (a, None)) => {
                        // x ^ 0 = x ; x ^ 1 = !x (and the xnor duals).
                        if c ^ inv {
                            n.gates[gi] = Gate {
                                kind: GateKind::Inv,
                                name: n.gates[gi].name.clone(),
                                inputs: vec![a],
                                output: out,
                            };
                            changed += 1;
                            continue;
                        } else {
                            set_alias(vals, a);
                        }
                    }
                    _ => {}
                }
            }
            GateKind::Mux2 => match resolved[0] {
                (_, Some(sel)) => {
                    let pick = if sel { resolved[2] } else { resolved[1] };
                    match pick {
                        (_, Some(b)) => set_const(vals, b),
                        (a, None) => set_alias(vals, a),
                    }
                }
                _ => {
                    // mux(s, a, a) = a
                    if resolved[1].0 == resolved[2].0 && resolved[1].1.is_none() {
                        set_alias(vals, resolved[1].0);
                    }
                }
            },
            GateKind::Dff => unreachable!(),
        }
        if matches!(before, NetVal::Unknown)
            && matches!(vals[out], NetVal::Const(_) | NetVal::Alias(_))
        {
            changed += 1;
        }
    }
    changed
}

/// Rebuild the netlist after folding: drop simplified gates, rewrite inputs,
/// and materialize Const/Buf drivers for primary outputs that simplified.
fn rebuild(n: &Netlist, vals: &[NetVal]) -> Netlist {
    let mut out = Netlist::new(&n.name);
    out.num_nets = n.num_nets;
    out.inputs = n.inputs.clone();
    out.outputs = n.outputs.clone();
    for g in &n.gates {
        if !matches!(vals[g.output], NetVal::Unknown) {
            continue; // replaced by const/alias
        }
        let inputs = g
            .inputs
            .iter()
            .map(|&i| {
                let (net, c) = resolve(vals, i);
                match c {
                    Some(_) => net, // keep pointing at the const net
                    None => net,
                }
            })
            .collect();
        out.gates.push(Gate { kind: g.kind, name: g.name.clone(), inputs, output: g.output });
    }
    // Const nets that are still referenced need a driver.
    let mut referenced: Vec<bool> = vec![false; n.num_nets];
    for g in &out.gates {
        for &i in &g.inputs {
            referenced[i] = true;
        }
    }
    for p in &out.outputs {
        for &b in &p.bits {
            referenced[b] = true;
        }
    }
    let driven: std::collections::HashSet<NetId> = out
        .gates
        .iter()
        .map(|g| g.output)
        .chain(out.inputs.iter().flat_map(|p| p.bits.iter().copied()))
        .collect();
    for net in 0..n.num_nets {
        if !referenced[net] || driven.contains(&net) {
            continue;
        }
        match vals[net] {
            NetVal::Const(b) => {
                let kind = if b { GateKind::Const1 } else { GateKind::Const0 };
                out.gates.push(Gate {
                    kind,
                    name: format!("fold_const_{net}"),
                    inputs: vec![],
                    output: net,
                });
            }
            NetVal::Alias(_) => {
                let (src, c) = resolve(vals, net);
                match c {
                    Some(b) => {
                        let kind = if b { GateKind::Const1 } else { GateKind::Const0 };
                        out.gates.push(Gate {
                            kind,
                            name: format!("fold_const_{net}"),
                            inputs: vec![],
                            output: net,
                        });
                    }
                    None => out.gates.push(Gate {
                        kind: GateKind::Buf,
                        name: format!("fold_alias_{net}"),
                        inputs: vec![src],
                        output: net,
                    }),
                }
            }
            NetVal::Unknown => {}
        }
    }
    out
}

/// Structural hashing: merge gates with identical (kind, inputs).
fn cse(n: &mut Netlist) -> usize {
    let mut table: HashMap<(GateKind, Vec<NetId>), NetId> = HashMap::new();
    let mut alias: HashMap<NetId, NetId> = HashMap::new();
    let mut kept = Vec::with_capacity(n.gates.len());
    let mut merged = 0;
    for g in n.gates.drain(..) {
        if g.kind == GateKind::Dff {
            kept.push(g);
            continue;
        }
        let mut key_inputs: Vec<NetId> =
            g.inputs.iter().map(|i| *alias.get(i).unwrap_or(i)).collect();
        let commutative = matches!(
            g.kind,
            GateKind::And2
                | GateKind::Nand2
                | GateKind::Or2
                | GateKind::Nor2
                | GateKind::Xor2
                | GateKind::Xnor2
        );
        if commutative {
            key_inputs.sort_unstable();
        }
        let key = (g.kind, key_inputs);
        match table.get(&key) {
            Some(&existing) => {
                alias.insert(g.output, existing);
                merged += 1;
            }
            None => {
                table.insert(key, g.output);
                kept.push(g);
            }
        }
    }
    for g in &mut kept {
        for i in g.inputs.iter_mut() {
            if let Some(&a) = alias.get(i) {
                *i = a;
            }
        }
    }
    // Primary outputs that were merged away need buf drivers.
    let driven: std::collections::HashSet<NetId> = kept
        .iter()
        .map(|g| g.output)
        .chain(n.inputs.iter().flat_map(|p| p.bits.iter().copied()))
        .collect();
    for p in n.outputs.clone() {
        for &b in &p.bits {
            if !driven.contains(&b) {
                if let Some(&src) = alias.get(&b) {
                    kept.push(Gate {
                        kind: GateKind::Buf,
                        name: format!("cse_alias_{b}"),
                        inputs: vec![src],
                        output: b,
                    });
                }
            }
        }
    }
    n.gates = kept;
    merged
}

/// Dead-code elimination: drop gates not reachable from any primary output.
fn dce(n: &mut Netlist) -> usize {
    let mut needed_nets: Vec<bool> = vec![false; n.num_nets];
    for p in &n.outputs {
        for &b in &p.bits {
            needed_nets[b] = true;
        }
    }
    let by_output: HashMap<NetId, usize> =
        n.gates.iter().enumerate().map(|(gi, g)| (g.output, gi)).collect();
    let mut needed_gates = vec![false; n.gates.len()];
    let mut stack: Vec<usize> = n
        .gates
        .iter()
        .enumerate()
        .filter(|(_, g)| needed_nets[g.output])
        .map(|(gi, _)| gi)
        .collect();
    for &gi in &stack {
        needed_gates[gi] = true;
    }
    while let Some(gi) = stack.pop() {
        for &i in &n.gates[gi].inputs {
            if !needed_nets[i] {
                needed_nets[i] = true;
            }
            if let Some(&pg) = by_output.get(&i) {
                if !needed_gates[pg] {
                    needed_gates[pg] = true;
                    stack.push(pg);
                }
            }
        }
    }
    let before = n.gates.len();
    let mut keep_iter = needed_gates.into_iter();
    n.gates.retain(|_| keep_iter.next().unwrap());
    before - n.gates.len()
}

/// Full generic-gate optimization to fixpoint.
pub fn optimize(n: &Netlist, stats: &mut SynthStats) -> Netlist {
    let mut cur = n.clone();
    for _round in 0..10 {
        // Fold to a fixpoint on the alias map BEFORE paying for a netlist
        // rebuild: constants discovered late in one sweep are visible to
        // earlier gates only on the next sweep, but sweeps over the alias
        // map are much cheaper than rebuilds (§Perf: 224 ms -> see
        // EXPERIMENTS.md for the 65x2 fabric).
        let mut vals = vec![NetVal::Unknown; cur.num_nets];
        let mut folded = 0;
        loop {
            let f = const_fold_round(&mut cur, &mut vals);
            folded += f;
            if f == 0 {
                break;
            }
        }
        stats.const_folded += folded;
        cur = rebuild(&cur, &vals);
        let merged = cse(&mut cur);
        stats.cse_merged += merged;
        let removed = dce(&mut cur);
        stats.dce_removed += removed;
        if folded + merged + removed == 0 {
            break;
        }
    }
    stats.gates_optimized = cur.gates.len();
    cur
}

// ---------------------------------------------------------------------------
// Technology mapping
// ---------------------------------------------------------------------------

fn intern_cell(cells: &mut Vec<Cell>, index: &mut HashMap<String, usize>, c: &Cell) -> usize {
    if let Some(&i) = index.get(&c.name) {
        return i;
    }
    cells.push(c.clone());
    index.insert(c.name.clone(), cells.len() - 1);
    cells.len() - 1
}

fn map_std(
    n: &Netlist,
    lib: &CellLibrary,
    cells: &mut Vec<Cell>,
    index: &mut HashMap<String, usize>,
    instances: &mut Vec<MappedInstance>,
) {
    for g in &n.gates {
        let c = lib.std_cell(g.kind);
        let ci = intern_cell(cells, index, c);
        instances.push(MappedInstance {
            name: g.name.clone(),
            cell: ci,
            inputs: g.inputs.clone(),
            outputs: vec![g.output],
            is_seq: g.kind.is_sequential(),
            is_macro: false,
        });
    }
}

/// Classify a hierarchy-group prefix into a TNN7 macro name.
fn macro_for_group(prefix: &str) -> Option<&'static str> {
    let last = prefix.rsplit('/').next().unwrap_or(prefix);
    if last.starts_with("syn") {
        Some("tnn7_synapse_rnl_stdp")
    } else if last == "tree" {
        Some("tnn7_adder8")
    } else if last == "wta" {
        Some("tnn7_wta4")
    } else if last.starts_with("enc") {
        Some("tnn7_encoder")
    } else {
        None
    }
}

/// Precomputed connectivity for fast group-boundary extraction: per net,
/// how many gates consume it and whether it is a primary output.
struct BoundaryIndex {
    consumer_count: Vec<u32>,
    is_primary_out: Vec<bool>,
}

impl BoundaryIndex {
    fn build(n: &Netlist) -> Self {
        let mut consumer_count = vec![0u32; n.num_nets];
        for g in &n.gates {
            for &i in &g.inputs {
                consumer_count[i] += 1;
            }
        }
        let mut is_primary_out = vec![false; n.num_nets];
        for p in &n.outputs {
            for &b in &p.bits {
                is_primary_out[b] = true;
            }
        }
        BoundaryIndex { consumer_count, is_primary_out }
    }
}

/// Boundary nets of a gate group: (external inputs, outputs used outside).
/// O(group size * fanin) thanks to the precomputed index — the naive
/// all-gates scan was quadratic over the whole design (see §Perf).
fn group_boundary(
    n: &Netlist,
    group: &[usize],
    idx: &BoundaryIndex,
) -> (Vec<NetId>, Vec<NetId>) {
    let produced: std::collections::HashSet<NetId> =
        group.iter().map(|&gi| n.gates[gi].output).collect();
    // Count how many consumers of each produced net are INSIDE the group.
    let mut inside_consumers: std::collections::HashMap<NetId, u32> =
        std::collections::HashMap::new();
    let mut ins: Vec<NetId> = Vec::new();
    let mut seen_in: std::collections::HashSet<NetId> = std::collections::HashSet::new();
    for &gi in group {
        for &i in &n.gates[gi].inputs {
            if produced.contains(&i) {
                *inside_consumers.entry(i).or_insert(0) += 1;
            } else if seen_in.insert(i) {
                ins.push(i);
            }
        }
    }
    let mut outs: Vec<NetId> = Vec::new();
    for &gi in group {
        let net = n.gates[gi].output;
        let inside = inside_consumers.get(&net).copied().unwrap_or(0);
        if idx.consumer_count[net] > inside || idx.is_primary_out[net] {
            outs.push(net);
        }
    }
    (ins, outs)
}

/// Map onto a library. For macro libraries (TNN7) the recognized hierarchy
/// groups become macro instances first and only the residual fabric is
/// optimized; for pure std-cell libraries the whole netlist is optimized
/// then 1:1 mapped.
pub fn synthesize(netlist: &Netlist, lib: &CellLibrary) -> MappedDesign {
    let t0 = Instant::now();
    let mut stats = SynthStats { gates_in: netlist.gates.len(), ..Default::default() };
    let mut cells = Vec::new();
    let mut index = HashMap::new();
    let mut instances = Vec::new();

    if lib.has_macros() {
        // Group at hierarchy depth 2 ("n3/syn17", "n3/tree", "enc5", "wta").
        let mut groups = netlist.groups_at_depth(2);
        let depth1 = netlist.groups_at_depth(1);
        for (k, v) in depth1 {
            // wta and enc groups live at depth 1.
            if macro_for_group(&k).is_some() && !groups.contains_key(&k) {
                groups.insert(k, v);
            }
        }
        let bidx = BoundaryIndex::build(netlist);
        let mut absorbed: Vec<bool> = vec![false; netlist.gates.len()];
        let mut keys: Vec<String> = groups.keys().cloned().collect();
        keys.sort();
        for key in keys {
            let Some(macro_name) = macro_for_group(&key) else { continue };
            let group = &groups[&key];
            let mc = lib.macro_cell(macro_name).expect("macro exists").clone();
            // Number of macro instances needed to absorb the group.
            let count = group.len().div_ceil(mc.gate_equivalents).max(1);
            let chunk = group.len().div_ceil(count);
            for (k2, part) in group.chunks(chunk).enumerate() {
                let (ins, outs) = group_boundary(netlist, part, &bidx);
                let has_seq = part.iter().any(|&gi| netlist.gates[gi].kind.is_sequential());
                let ci = intern_cell(&mut cells, &mut index, &mc);
                instances.push(MappedInstance {
                    name: format!("{key}/{}_{k2}", mc.name),
                    cell: ci,
                    inputs: ins,
                    outputs: outs,
                    is_seq: has_seq,
                    is_macro: true,
                });
                stats.macro_instances += 1;
            }
            for &gi in group {
                absorbed[gi] = true;
            }
        }
        // Residual fabric: everything not absorbed, optimized as a
        // sub-netlist with pseudo-boundaries.
        let mut residual = Netlist::new(&format!("{}_residual", netlist.name));
        residual.num_nets = netlist.num_nets;
        residual.inputs = netlist.inputs.clone();
        residual.outputs = netlist.outputs.clone();
        for (gi, g) in netlist.gates.iter().enumerate() {
            if !absorbed[gi] {
                residual.gates.push(g.clone());
            }
        }
        // Macro boundary nets become pseudo inputs/outputs of the residual.
        let mut pseudo_in: Vec<NetId> = Vec::new();
        let mut pseudo_out: Vec<NetId> = Vec::new();
        for inst in &instances {
            pseudo_in.extend(inst.outputs.iter().copied());
            pseudo_out.extend(inst.inputs.iter().copied());
        }
        residual.add_input("__macro_outs", pseudo_in);
        residual.add_output("__macro_ins", pseudo_out);
        let optimized = optimize(&residual, &mut stats);
        map_std(&optimized, lib, &mut cells, &mut index, &mut instances);
    } else {
        let optimized = optimize(netlist, &mut stats);
        map_std(&optimized, lib, &mut cells, &mut index, &mut instances);
    }

    stats.std_instances = instances.iter().filter(|i| !i.is_macro).count();
    stats.runtime_s = t0.elapsed().as_secs_f64();
    MappedDesign {
        name: netlist.name.clone(),
        library: lib.name.clone(),
        cells,
        instances,
        num_nets: netlist.num_nets,
        primary_inputs: netlist.inputs.iter().flat_map(|p| p.bits.iter().copied()).collect(),
        primary_outputs: netlist.outputs.iter().flat_map(|p| p.bits.iter().copied()).collect(),
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ColumnConfig;
    use crate::eda::cells::{asap7, tnn7};
    use crate::rtl::builder::Builder;
    use crate::rtl::generate_column;

    fn opt_roundtrip(n: &Netlist) -> Netlist {
        let mut stats = SynthStats::default();
        optimize(n, &mut stats)
    }

    #[test]
    fn const_folding_collapses_constant_logic() {
        let mut n = Netlist::new("cf");
        let a = n.new_net();
        n.add_input("a", vec![a]);
        let mut b = Builder::new(&mut n);
        let one = b.one();
        let x = b.and(a, one); // = a
        let zero = b.zero();
        let y = b.or(x, zero); // = a
        let z = b.xor(y, one); // = !a
        n.add_output("z", vec![z]);
        let opt = opt_roundtrip(&n);
        // Everything should fold down to a single inverter (plus possibly a
        // buf for the output alias).
        assert!(opt.gates.len() <= 2, "{} gates left", opt.gates.len());
        assert!(opt.gates.iter().any(|g| g.kind == GateKind::Inv));
    }

    #[test]
    fn cse_merges_duplicate_gates() {
        let mut n = Netlist::new("cse");
        let a = n.new_net();
        let b_ = n.new_net();
        n.add_input("a", vec![a]);
        n.add_input("b", vec![b_]);
        let mut b = Builder::new(&mut n);
        let x1 = b.and(a, b_);
        let x2 = b.and(b_, a); // commutative duplicate
        let y = b.or(x1, x2); // or(x, x) -> mux? stays, but inputs merge
        n.add_output("y", vec![y]);
        let opt = opt_roundtrip(&n);
        let ands = opt.gates.iter().filter(|g| g.kind == GateKind::And2).count();
        assert_eq!(ands, 1);
    }

    #[test]
    fn dce_drops_unused_logic() {
        let mut n = Netlist::new("dce");
        let a = n.new_net();
        n.add_input("a", vec![a]);
        let mut b = Builder::new(&mut n);
        let used = b.not(a);
        let _unused = b.and(a, used);
        n.add_output("o", vec![used]);
        let opt = opt_roundtrip(&n);
        assert_eq!(opt.gates.len(), 1);
    }

    #[test]
    fn optimization_preserves_column_behavior() {
        // The optimized netlist must simulate identically to the original.
        let cfg = ColumnConfig::new("OptTest", "synthetic", 6, 2);
        let rtl = generate_column(&cfg).unwrap();
        let opt = opt_roundtrip(&rtl.netlist);
        assert!(opt.gates.len() < rtl.netlist.gates.len(), "opt should shrink");
        opt.validate().unwrap();
        let opt_rtl = crate::rtl::ColumnRtl {
            netlist: opt,
            config: rtl.config.clone(),
            theta_fp: rtl.theta_fp,
            v_bits: rtl.v_bits,
            winner_bits: rtl.winner_bits,
        };
        let mut sim_a = crate::rtl::GateSim::new(&rtl.netlist).unwrap();
        let mut sim_b = crate::rtl::GateSim::new(&opt_rtl.netlist).unwrap();
        let w = vec![vec![20u64, 8, 40, 0, 56, 16], vec![4, 28, 12, 44, 36, 24]];
        rtl.load_weights(&mut sim_a, &w);
        opt_rtl.load_weights(&mut sim_b, &w);
        for step in 0..10 {
            let s: Vec<i32> = (0..6).map(|i| ((step * 3 + i * 5) % 9) as i32).collect();
            let (wa, ya) = rtl.run_sample(&mut sim_a, &s, true);
            let (wb, yb) = opt_rtl.run_sample(&mut sim_b, &s, true);
            assert_eq!((wa, &ya), (wb, &yb), "step {step}");
            assert_eq!(rtl.read_weights(&sim_a), opt_rtl.read_weights(&sim_b));
        }
    }

    #[test]
    fn asap7_mapping_covers_all_gates() {
        let cfg = ColumnConfig::new("MapTest", "synthetic", 8, 2);
        let rtl = generate_column(&cfg).unwrap();
        let design = synthesize(&rtl.netlist, &asap7());
        assert_eq!(design.stats.macro_instances, 0);
        assert!(design.stats.std_instances > 0);
        assert!(design.area_um2() > 0.0);
        assert!(design.stats.gates_optimized < design.stats.gates_in);
    }

    #[test]
    fn tnn7_mapping_uses_macros_and_shrinks() {
        let cfg = ColumnConfig::new("MacroTest", "synthetic", 8, 2);
        let rtl = generate_column(&cfg).unwrap();
        let asap = synthesize(&rtl.netlist, &asap7());
        let tnn = synthesize(&rtl.netlist, &tnn7());
        assert!(tnn.stats.macro_instances >= 8 * 2, "one macro per synapse at least");
        assert!(tnn.instances.len() < asap.instances.len() / 2);
        assert!(tnn.area_um2() < asap.area_um2());
        assert!(tnn.leakage_nw() < asap.leakage_nw());
    }

    #[test]
    fn macro_groups_classified() {
        assert_eq!(macro_for_group("n3/syn17"), Some("tnn7_synapse_rnl_stdp"));
        assert_eq!(macro_for_group("n0/tree"), Some("tnn7_adder8"));
        assert_eq!(macro_for_group("wta"), Some("tnn7_wta4"));
        assert_eq!(macro_for_group("enc5"), Some("tnn7_encoder"));
        assert_eq!(macro_for_group("seq"), None);
    }
}
