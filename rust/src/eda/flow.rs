//! Flow orchestration: RTL -> synthesis -> placement -> routing -> STA ->
//! power, with per-stage wall-clock measurement (the data behind Fig 3 and
//! the §III-C runtime claims).

use std::time::Instant;

use anyhow::Result;

use crate::config::ColumnConfig;
use crate::rtl::{generate_column_silicon, ColumnRtl};

use super::library::CellLibrary;
use super::placement::{place, PlaceOpts, Placement};
use super::power::{self, PowerReport, DEFAULT_ACTIVITY};
use super::routing::{route, RoutingResult};
use super::sta::{analyze as sta_analyze, computation_latency_ns, TimingReport};
use super::synthesis::{synthesize, MappedDesign};

/// Per-stage wall-clock runtimes (seconds).
#[derive(Debug, Clone, Default)]
pub struct StageRuntimes {
    pub rtl_gen_s: f64,
    pub synthesis_s: f64,
    pub placement_s: f64,
    pub routing_s: f64,
    pub sta_s: f64,
    pub power_s: f64,
}

impl StageRuntimes {
    /// Place-and-route runtime (the Fig-3 metric).
    pub fn pnr_s(&self) -> f64 {
        self.placement_s + self.routing_s
    }
    /// Full hardware process flow (the §III-C -47% metric).
    pub fn full_flow_s(&self) -> f64 {
        self.synthesis_s + self.pnr_s() + self.sta_s + self.power_s
    }
}

/// Complete post-layout report for one (design, library) flow run.
#[derive(Debug, Clone)]
pub struct FlowReport {
    pub design: String,
    pub tag: String,
    pub library: String,
    pub synapse_count: usize,
    pub gates_in: usize,
    pub instances: usize,
    pub macro_instances: usize,
    /// Post-layout die area (um^2) — the Table-IV metric.
    pub die_area_um2: f64,
    pub cell_area_um2: f64,
    /// Post-layout leakage — the Table-III metric.
    pub leakage_uw: f64,
    pub power: PowerReport,
    pub timing: TimingReport,
    /// Per-sample computation latency (ns) — the Fig-2 metric.
    pub latency_ns: f64,
    pub wirelength_um: f64,
    pub runtimes: StageRuntimes,
}

/// Flow options.
#[derive(Debug, Clone, Default)]
pub struct FlowOpts {
    pub place: PlaceOpts,
    /// Override the operating frequency for power (default: fmax).
    pub freq_mhz: Option<f64>,
    pub activity: Option<f64>,
}

/// Run the full hardware flow for one column config on one library.
pub fn run_flow(cfg: &ColumnConfig, lib: &CellLibrary, opts: &FlowOpts) -> Result<FlowReport> {
    let t0 = Instant::now();
    let rtl = generate_column_silicon(cfg)?;
    let rtl_gen_s = t0.elapsed().as_secs_f64();
    run_flow_on_rtl(&rtl, lib, opts, rtl_gen_s)
}

/// Run the flow on pre-generated RTL (lets benches reuse the netlist).
pub fn run_flow_on_rtl(
    rtl: &ColumnRtl,
    lib: &CellLibrary,
    opts: &FlowOpts,
    rtl_gen_s: f64,
) -> Result<FlowReport> {
    let cfg = &rtl.config;

    let t = Instant::now();
    let design: MappedDesign = synthesize(&rtl.netlist, lib);
    let synthesis_s = t.elapsed().as_secs_f64();

    let t = Instant::now();
    let placement: Placement = place(&design, &opts.place);
    let placement_s = t.elapsed().as_secs_f64();

    let t = Instant::now();
    let routing: RoutingResult = route(&design, &placement);
    let routing_s = t.elapsed().as_secs_f64();

    let t = Instant::now();
    let timing = sta_analyze(&design, lib, &routing)?;
    let sta_s = t.elapsed().as_secs_f64();

    let t = Instant::now();
    let freq = opts.freq_mhz.unwrap_or(timing.fmax_mhz);
    let activity = opts.activity.unwrap_or(DEFAULT_ACTIVITY);
    let power = power::analyze(&design, lib, &routing, freq, activity);
    let power_s = t.elapsed().as_secs_f64();

    let latency_ns = computation_latency_ns(timing.clock_period_ps, cfg.params.t_r);

    Ok(FlowReport {
        design: cfg.name.clone(),
        tag: cfg.tag(),
        library: lib.name.clone(),
        synapse_count: cfg.synapse_count(),
        gates_in: design.stats.gates_in,
        instances: design.instances.len(),
        macro_instances: design.stats.macro_instances,
        die_area_um2: placement.die_area_um2,
        cell_area_um2: placement.cell_area_um2,
        leakage_uw: power.leakage_uw(),
        power,
        timing,
        latency_ns,
        wirelength_um: routing.wirelength_um,
        runtimes: StageRuntimes {
            rtl_gen_s,
            synthesis_s,
            placement_s,
            routing_s,
            sta_s,
            power_s,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ColumnConfig;
    use crate::eda::cells::{asap7, tnn7};

    #[test]
    fn flow_produces_complete_report() {
        let cfg = ColumnConfig::new("FlowTest", "synthetic", 8, 2);
        let r = run_flow(&cfg, &asap7(), &FlowOpts::default()).unwrap();
        assert_eq!(r.synapse_count, 16);
        assert!(r.die_area_um2 > 0.0);
        assert!(r.leakage_uw > 0.0);
        assert!(r.latency_ns > 0.0);
        assert!(r.runtimes.full_flow_s() > 0.0);
    }

    #[test]
    fn tnn7_flow_beats_asap7_on_area_leakage_and_instances() {
        let cfg = ColumnConfig::new("FlowCmp", "synthetic", 12, 2);
        let a = run_flow(&cfg, &asap7(), &FlowOpts::default()).unwrap();
        let t = run_flow(&cfg, &tnn7(), &FlowOpts::default()).unwrap();
        assert!(t.die_area_um2 < a.die_area_um2);
        assert!(t.leakage_uw < a.leakage_uw);
        assert!(t.instances < a.instances);
        assert!(t.macro_instances > 0);
    }
}
