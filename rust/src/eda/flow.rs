//! Flow orchestration: RTL -> synthesis -> placement -> routing -> STA ->
//! power, with per-stage wall-clock measurement (the data behind Fig 3 and
//! the §III-C runtime claims), plus the parallel, cached **flow campaign
//! runner** that executes many (design, library) flows on the
//! `coordinator::jobs` worker pool.
//!
//! Campaign determinism contract (inherited from PR 1's worker pool):
//! [`FlowCampaign::run`] returns reports in job order for any worker
//! count, and every metric field of a report is a pure function of
//! (config, library, opts) — only the measured [`StageRuntimes`] are
//! wall-clock and excluded from the byte-identity guarantee. With a
//! [`FlowCache`] attached, completed flows are skipped entirely on
//! re-runs and served from disk.

use std::time::Instant;

use anyhow::Result;

use crate::config::ColumnConfig;
use crate::obs::trace;
use crate::rtl::{generate_column_silicon, ColumnRtl};

use super::cache::FlowCache;
use super::library::CellLibrary;
use super::placement::{place, PlaceOpts, Placement};
use super::power::{self, PowerReport, DEFAULT_ACTIVITY};
use super::routing::{route, RoutingResult};
use super::sta::{analyze as sta_analyze, computation_latency_ns, TimingReport};
use super::synthesis::{synthesize, MappedDesign};

/// Per-stage wall-clock runtimes (seconds). These are measurement data:
/// they vary run to run and machine to machine, and are deliberately
/// excluded from the campaign byte-identity contract (cached reports
/// carry the runtimes of the run that populated the cache).
#[derive(Debug, Clone, Default)]
pub struct StageRuntimes {
    /// RTL generation (netlist construction) wall-clock.
    pub rtl_gen_s: f64,
    /// Logic synthesis (optimization + tech mapping) wall-clock.
    pub synthesis_s: f64,
    /// Simulated-annealing placement wall-clock.
    pub placement_s: f64,
    /// Global routing wall-clock.
    pub routing_s: f64,
    /// Static timing analysis wall-clock.
    pub sta_s: f64,
    /// Power analysis wall-clock.
    pub power_s: f64,
}

impl StageRuntimes {
    /// Place-and-route runtime (the Fig-3 metric).
    pub fn pnr_s(&self) -> f64 {
        self.placement_s + self.routing_s
    }
    /// Full hardware process flow (the §III-C -47% metric).
    pub fn full_flow_s(&self) -> f64 {
        self.synthesis_s + self.pnr_s() + self.sta_s + self.power_s
    }
}

/// Complete post-layout report for one (design, library) flow run.
#[derive(Debug, Clone)]
pub struct FlowReport {
    /// Design (benchmark) name, e.g. `ECG200`.
    pub design: String,
    /// Geometry tag, e.g. `96x2`.
    pub tag: String,
    /// Cell-library name the flow targeted.
    pub library: String,
    /// Total synapses (`p * q`) — the x-axis of every paper fit.
    pub synapse_count: usize,
    /// Generic gates entering synthesis.
    pub gates_in: usize,
    /// Mapped instances (std cells + macros) after synthesis.
    pub instances: usize,
    /// Macro instances among them (0 for pure std-cell libraries).
    pub macro_instances: usize,
    /// Post-layout die area (um^2) — the Table-IV metric.
    pub die_area_um2: f64,
    /// Summed standard-cell/macro area (um^2).
    pub cell_area_um2: f64,
    /// Post-layout leakage — the Table-III metric.
    pub leakage_uw: f64,
    /// Full power breakdown (leakage + dynamic at the operating point).
    pub power: PowerReport,
    /// Static timing: critical path, clock period, fmax.
    pub timing: TimingReport,
    /// Per-sample computation latency (ns) — the Fig-2 metric.
    pub latency_ns: f64,
    /// Total routed wirelength (um).
    pub wirelength_um: f64,
    /// Measured per-stage wall-clock (see [`StageRuntimes`]).
    pub runtimes: StageRuntimes,
}

/// Flow options.
#[derive(Debug, Clone, Default)]
pub struct FlowOpts {
    /// Placement effort/seed/floorplan options.
    pub place: PlaceOpts,
    /// Override the operating frequency for power (default: fmax).
    pub freq_mhz: Option<f64>,
    /// Override the switching activity for dynamic power.
    pub activity: Option<f64>,
}

/// Run the full hardware flow for one column config on one library.
///
/// Deterministic: every metric field of the returned report is a pure
/// function of `(cfg, lib, opts)`; only [`FlowReport::runtimes`] is
/// wall-clock.
///
/// ```
/// use tnngen::config::ColumnConfig;
/// use tnngen::eda::{run_flow, tnn7, FlowOpts};
///
/// let cfg = ColumnConfig::new("DocFlow", "synthetic", 8, 2);
/// let r = run_flow(&cfg, &tnn7(), &FlowOpts::default()).unwrap();
/// assert_eq!(r.synapse_count, 16);
/// assert!(r.die_area_um2 > 0.0);
/// assert!(r.leakage_uw > 0.0);
/// assert!(r.macro_instances > 0); // TNN7 maps synapses onto macros
/// ```
pub fn run_flow(cfg: &ColumnConfig, lib: &CellLibrary, opts: &FlowOpts) -> Result<FlowReport> {
    let t0 = Instant::now();
    let rtl = generate_column_silicon(cfg)?;
    let rtl_gen_s = stage_s("eda.rtl_gen", t0);
    run_flow_on_rtl(&rtl, lib, opts, rtl_gen_s)
}

/// Close one flow stage: record an `eda.*` trace span from `start` to now
/// (free when tracing is off) and return the stage wall-clock in seconds —
/// the span and the [`StageRuntimes`] figure are the same measurement.
fn stage_s(name: &'static str, start: Instant) -> f64 {
    let end = Instant::now();
    trace::record_range(name, "eda", start, end);
    end.duration_since(start).as_secs_f64()
}

/// Run the flow on pre-generated RTL (lets benches reuse the netlist).
pub fn run_flow_on_rtl(
    rtl: &ColumnRtl,
    lib: &CellLibrary,
    opts: &FlowOpts,
    rtl_gen_s: f64,
) -> Result<FlowReport> {
    let cfg = &rtl.config;

    let t = Instant::now();
    let design: MappedDesign = synthesize(&rtl.netlist, lib);
    let synthesis_s = stage_s("eda.synthesis", t);

    let t = Instant::now();
    let placement: Placement = place(&design, &opts.place);
    let placement_s = stage_s("eda.placement", t);

    let t = Instant::now();
    let routing: RoutingResult = route(&design, &placement);
    let routing_s = stage_s("eda.routing", t);

    let t = Instant::now();
    let timing = sta_analyze(&design, lib, &routing)?;
    let sta_s = stage_s("eda.sta", t);

    let t = Instant::now();
    let freq = opts.freq_mhz.unwrap_or(timing.fmax_mhz);
    let activity = opts.activity.unwrap_or(DEFAULT_ACTIVITY);
    let power = power::analyze(&design, lib, &routing, freq, activity);
    let power_s = stage_s("eda.power", t);

    let latency_ns = computation_latency_ns(timing.clock_period_ps, cfg.params.t_r);

    Ok(FlowReport {
        design: cfg.name.clone(),
        tag: cfg.tag(),
        library: lib.name.clone(),
        synapse_count: cfg.synapse_count(),
        gates_in: design.stats.gates_in,
        instances: design.instances.len(),
        macro_instances: design.stats.macro_instances,
        die_area_um2: placement.die_area_um2,
        cell_area_um2: placement.cell_area_um2,
        leakage_uw: power.leakage_uw(),
        power,
        timing,
        latency_ns,
        wirelength_um: routing.wirelength_um,
        runtimes: StageRuntimes {
            rtl_gen_s,
            synthesis_s,
            placement_s,
            routing_s,
            sta_s,
            power_s,
        },
    })
}

/// [`run_flow`] with an optional flow-report cache in front: a decodable
/// cached entry for the content key is returned without running any flow
/// stage; a miss runs the flow and populates the cache.
pub fn run_flow_cached(
    cfg: &ColumnConfig,
    lib: &CellLibrary,
    opts: &FlowOpts,
    cache: Option<&FlowCache>,
) -> Result<FlowReport> {
    let Some(cache) = cache else { return run_flow(cfg, lib, opts) };
    let key = FlowCache::key(cfg, lib, opts);
    let t = Instant::now();
    if let Some(report) = cache.lookup(key) {
        trace::record_range("eda.cache_hit", "eda", t, Instant::now());
        return Ok(report);
    }
    trace::record_range("eda.cache_miss", "eda", t, Instant::now());
    let report = run_flow(cfg, lib, opts)?;
    cache.store(key, &report)?;
    Ok(report)
}

/// One unit of campaign work: a (design, library, options) triple.
#[derive(Debug, Clone)]
pub struct FlowJob {
    /// The column design to run.
    pub config: ColumnConfig,
    /// The target cell library.
    pub library: CellLibrary,
    /// Flow options (placement effort, operating point).
    pub opts: FlowOpts,
}

impl FlowJob {
    /// Convenience constructor.
    pub fn new(config: ColumnConfig, library: CellLibrary, opts: FlowOpts) -> Self {
        FlowJob { config, library, opts }
    }
}

/// Parallel, cached campaign runner for hardware flows.
///
/// Runs one flow per worker on the `coordinator::jobs` pool
/// ([`crate::coordinator::jobs::parallel_map_workers`]); results come
/// back **in job order regardless of scheduling**, so campaign output is
/// reproducible for any worker count. An optional [`FlowCache`] makes
/// repeated campaigns resumable: completed flows are served from disk and
/// skip every flow stage.
#[derive(Debug)]
pub struct FlowCampaign {
    workers: usize,
    cache: Option<FlowCache>,
}

impl Default for FlowCampaign {
    /// All cores, no cache.
    fn default() -> Self {
        FlowCampaign {
            workers: crate::coordinator::jobs::default_workers(),
            cache: None,
        }
    }
}

impl FlowCampaign {
    /// Campaign pinned to exactly `workers` threads (min 1), no cache.
    pub fn with_workers(workers: usize) -> Self {
        FlowCampaign { workers: workers.max(1), cache: None }
    }

    /// Attach an on-disk flow-report cache rooted at `dir` (created on
    /// demand).
    pub fn with_cache_dir(mut self, dir: impl AsRef<std::path::Path>) -> Result<Self> {
        self.cache = Some(FlowCache::new(dir)?);
        Ok(self)
    }

    /// Worker threads this campaign uses.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The attached cache, if any.
    pub fn cache(&self) -> Option<&FlowCache> {
        self.cache.as_ref()
    }

    /// Cache hits so far (0 without a cache).
    pub fn cache_hits(&self) -> usize {
        self.cache.as_ref().map_or(0, |c| c.hits())
    }

    /// Cache misses so far (0 without a cache).
    pub fn cache_misses(&self) -> usize {
        self.cache.as_ref().map_or(0, |c| c.misses())
    }

    /// Run every job, one flow per worker on the persistent shared pool
    /// (no thread spawn per campaign), returning reports **in job order**
    /// (independent of thread scheduling). All jobs run even if one
    /// fails; the first error in job order is returned.
    pub fn run(&self, jobs: Vec<FlowJob>) -> Result<Vec<FlowReport>> {
        let cache = self.cache.as_ref();
        crate::coordinator::jobs::parallel_try_map_workers(jobs, self.workers, move |job| {
            run_flow_cached(&job.config, &job.library, &job.opts, cache)
        })
    }

    /// Run a single flow through the campaign's cache.
    pub fn run_one(
        &self,
        cfg: &ColumnConfig,
        lib: &CellLibrary,
        opts: &FlowOpts,
    ) -> Result<FlowReport> {
        run_flow_cached(cfg, lib, opts, self.cache.as_ref())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ColumnConfig;
    use crate::eda::cells::{asap7, tnn7};

    #[test]
    fn flow_produces_complete_report() {
        let cfg = ColumnConfig::new("FlowTest", "synthetic", 8, 2);
        let r = run_flow(&cfg, &asap7(), &FlowOpts::default()).unwrap();
        assert_eq!(r.synapse_count, 16);
        assert!(r.die_area_um2 > 0.0);
        assert!(r.leakage_uw > 0.0);
        assert!(r.latency_ns > 0.0);
        assert!(r.runtimes.full_flow_s() > 0.0);
    }

    #[test]
    fn tnn7_flow_beats_asap7_on_area_leakage_and_instances() {
        let cfg = ColumnConfig::new("FlowCmp", "synthetic", 12, 2);
        let a = run_flow(&cfg, &asap7(), &FlowOpts::default()).unwrap();
        let t = run_flow(&cfg, &tnn7(), &FlowOpts::default()).unwrap();
        assert!(t.die_area_um2 < a.die_area_um2);
        assert!(t.leakage_uw < a.leakage_uw);
        assert!(t.instances < a.instances);
        assert!(t.macro_instances > 0);
    }

    #[test]
    fn campaign_preserves_job_order() {
        let jobs: Vec<FlowJob> = [(6usize, 2usize), (10, 2), (8, 2)]
            .iter()
            .map(|&(p, q)| {
                FlowJob::new(
                    ColumnConfig::new(&format!("ord{p}x{q}"), "synthetic", p, q),
                    asap7(),
                    FlowOpts::default(),
                )
            })
            .collect();
        let reports = FlowCampaign::with_workers(3).run(jobs).unwrap();
        let tags: Vec<&str> = reports.iter().map(|r| r.tag.as_str()).collect();
        assert_eq!(tags, vec!["6x2", "10x2", "8x2"]);
    }

    #[test]
    fn uncached_campaign_reports_zero_cache_traffic() {
        let c = FlowCampaign::with_workers(2);
        assert_eq!(c.cache_hits(), 0);
        assert_eq!(c.cache_misses(), 0);
        assert!(c.cache().is_none());
    }
}
