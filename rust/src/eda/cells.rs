//! Concrete cell-library data: FreePDK45, ASAP7 and TNN7.
//!
//! Geometry anchors come from the published PDKs (ASAP7: 270 nm row height,
//! 54 nm CPP, NAND2 ~4 CPP; FreePDK45: 1.4 um rows). Leakage and delay
//! values are then fine-tuned so the *flow outputs* land on the paper's
//! Tables III/IV per-synapse aggregates (see DESIGN.md §Calibration and the
//! calibration tests in `rust/tests/integration.rs`):
//!
//! * FreePDK45  ~110  um^2 / 2.3 uW per synapse
//! * ASAP7      ~7.8  um^2 / 7.2 nW per synapse
//! * TNN7       ~5.3  um^2 / 4.5 nW per synapse (macros: ref [8])

use crate::rtl::GateKind;

use super::library::{Cell, CellLibrary, TechParams};

fn cell(name: &str, area: f64, leak_nw: f64, delay_ps: f64, cap_ff: f64, energy_fj: f64) -> Cell {
    Cell {
        name: name.to_string(),
        area_um2: area,
        leakage_nw: leak_nw,
        delay_ps,
        input_cap_ff: cap_ff,
        switch_energy_fj: energy_fj,
        gate_equivalents: 1,
    }
}

/// FreePDK45: 45 nm bulk CMOS (open PDK of ref [10]).
pub fn freepdk45() -> CellLibrary {
    let tech = TechParams {
        row_height_um: 1.4,
        wire_delay_ps_per_um: 2.5,
        wire_cap_ff_per_um: 0.20,
        utilization: 0.70,
        vdd: 1.1,
    };
    let mut lib = CellLibrary::new("FreePDK45", 45, tech);
    // (name, area um^2, leakage nW, delay ps, cap fF, energy fJ)
    lib.add_std_cell(GateKind::Const0, cell("TIELO_X1", 0.1377, 2.1300, 0.6, 0.0, 0.00));
    lib.add_std_cell(GateKind::Const1, cell("TIEHI_X1", 0.1377, 2.1300, 0.6, 0.0, 0.00));
    lib.add_std_cell(GateKind::Buf, cell("BUF_X1", 0.2713, 8.5200, 22.8, 1.6, 5.60));
    lib.add_std_cell(GateKind::Inv, cell("INV_X1", 0.2035, 6.9225, 13.2, 1.5, 4.40));
    lib.add_std_cell(GateKind::And2, cell("AND2_X1", 0.3733, 11.7150, 31.2, 1.7, 7.20));
    lib.add_std_cell(GateKind::Nand2, cell("NAND2_X1", 0.2713, 10.1175, 18.0, 1.6, 6.00));
    lib.add_std_cell(GateKind::Or2, cell("OR2_X1", 0.3733, 12.2475, 32.4, 1.7, 7.20));
    lib.add_std_cell(GateKind::Nor2, cell("NOR2_X1", 0.2713, 10.4370, 19.2, 1.6, 6.00));
    lib.add_std_cell(GateKind::Xor2, cell("XOR2_X1", 0.5426, 17.0400, 40.8, 2.2, 10.40));
    lib.add_std_cell(GateKind::Xnor2, cell("XNOR2_X1", 0.5426, 17.0400, 40.8, 2.2, 10.40));
    lib.add_std_cell(GateKind::Mux2, cell("MUX2_X1", 0.6783, 19.1700, 44.4, 2.3, 11.60));
    lib.add_std_cell(GateKind::Dff, cell("DFF_X1", 2.3062, 61.7700, 66.0, 2.8, 30.00));
    lib
}

/// ASAP7: 7 nm FinFET predictive PDK (ref [3]). RVT, typical corner.
pub fn asap7() -> CellLibrary {
    let tech = TechParams {
        row_height_um: 0.27,
        wire_delay_ps_per_um: 0.8,
        wire_cap_ff_per_um: 0.11,
        utilization: 0.70,
        vdd: 0.70,
    };
    let mut lib = CellLibrary::new("ASAP7", 7, tech);
    lib.add_std_cell(GateKind::Const0, cell("TIELOx1_ASAP7", 0.0113, 0.0064, 0.6, 0.0, 0.00));
    lib.add_std_cell(GateKind::Const1, cell("TIEHIx1_ASAP7", 0.0113, 0.0064, 0.6, 0.0, 0.00));
    lib.add_std_cell(GateKind::Buf, cell("BUFx2_ASAP7", 0.0225, 0.0277, 8.4, 0.30, 0.44));
    lib.add_std_cell(GateKind::Inv, cell("INVx1_ASAP7", 0.0169, 0.0213, 4.8, 0.28, 0.32));
    lib.add_std_cell(GateKind::And2, cell("AND2x2_ASAP7", 0.0276, 0.0362, 11.4, 0.32, 0.56));
    lib.add_std_cell(GateKind::Nand2, cell("NAND2xp5_ASAP7", 0.0241, 0.0309, 6.6, 0.30, 0.48));
    lib.add_std_cell(GateKind::Or2, cell("OR2x2_ASAP7", 0.0276, 0.0373, 12.0, 0.32, 0.56));
    lib.add_std_cell(GateKind::Nor2, cell("NOR2xp5_ASAP7", 0.0241, 0.0319, 7.2, 0.30, 0.48));
    lib.add_std_cell(GateKind::Xor2, cell("XOR2xp5_ASAP7", 0.0420, 0.0554, 14.4, 0.42, 0.80));
    lib.add_std_cell(GateKind::Xnor2, cell("XNOR2xp5_ASAP7", 0.0420, 0.0554, 14.4, 0.42, 0.80));
    lib.add_std_cell(GateKind::Mux2, cell("MUX2xp5_ASAP7", 0.0476, 0.0639, 16.2, 0.45, 0.92));
    lib.add_std_cell(GateKind::Dff, cell("DFFHQx4_ASAP7", 0.1377, 0.2077, 24.0, 0.55, 2.40));
    lib
}

/// TNN7: ASAP7 std cells plus the custom TNN macro suite of ref [8].
///
/// Each macro is a full-custom layout of a recurring TNN block; density and
/// shared diffusion give it ~0.5-0.6x the area and leakage of the std-cell
/// group it replaces. `gate_equivalents` is the generic-gate capacity the
/// synthesis mapper uses when collapsing a hierarchy group into macro
/// instances.
pub fn tnn7() -> CellLibrary {
    let mut lib = asap7();
    lib.name = "TNN7".to_string();
    // Synapse macro: 6-bit weight reg + response gating + full STDP update
    // unit (the `n*/syn*` hierarchy group, ~100 generic gates incl. 6 DFF).
    lib.add_macro(Cell {
        name: "tnn7_synapse_rnl_stdp".to_string(),
        area_um2: 1.45,
        leakage_nw: 1.75,
        delay_ps: 30.0,
        input_cap_ff: 0.9,
        switch_energy_fj: 9.6,
        gate_equivalents: 100,
    });
    // Compound 8-bit adder macro for the neuron body adder trees.
    lib.add_macro(Cell {
        name: "tnn7_adder8".to_string(),
        area_um2: 0.55,
        leakage_nw: 0.62,
        delay_ps: 34.0,
        input_cap_ff: 0.8,
        switch_energy_fj: 6.4,
        gate_equivalents: 40,
    });
    // 4-way earliest-spike WTA slice.
    lib.add_macro(Cell {
        name: "tnn7_wta4".to_string(),
        area_um2: 0.90,
        leakage_nw: 0.70,
        delay_ps: 20.0,
        input_cap_ff: 0.7,
        switch_energy_fj: 6.0,
        gate_equivalents: 42,
    });
    // Input interface slice: arrival comparator + has-in/le comparators.
    lib.add_macro(Cell {
        name: "tnn7_encoder".to_string(),
        area_um2: 0.10,
        leakage_nw: 0.10,
        delay_ps: 22.0,
        input_cap_ff: 0.7,
        switch_energy_fj: 6.4,
        gate_equivalents: 48,
    });
    lib
}

/// All three libraries, in the paper's table order.
pub fn all_libraries() -> Vec<CellLibrary> {
    vec![freepdk45(), asap7(), tnn7()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn library_names_and_nodes() {
        let libs = all_libraries();
        assert_eq!(
            libs.iter().map(|l| l.name.as_str()).collect::<Vec<_>>(),
            vec!["FreePDK45", "ASAP7", "TNN7"]
        );
        assert_eq!(libs[0].node_nm, 45);
        assert_eq!(libs[1].node_nm, 7);
        assert_eq!(libs[2].node_nm, 7);
    }

    #[test]
    fn asap7_geometry_anchors() {
        let a = asap7();
        assert!((a.tech.row_height_um - 0.27).abs() < 1e-9);
        let nand = a.std_cell(GateKind::Nand2);
        // NAND2 ~ 3-4 CPP x row height, times the effective-density factor
        // of the calibrated flow (area recovery + drive-size mix).
        assert!(nand.area_um2 > 0.015 && nand.area_um2 < 0.06);
    }

    #[test]
    fn tnn7_macros_have_positive_capacity() {
        let t = tnn7();
        for m in t.macro_names() {
            let c = t.macro_cell(m).unwrap();
            assert!(c.gate_equivalents > 1, "{m}");
            assert!(c.area_um2 > 0.0 && c.leakage_nw > 0.0);
        }
    }
}
