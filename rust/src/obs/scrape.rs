//! Live metrics endpoint: a tiny HTTP/1.0 responder over
//! [`Registry`] renderings.
//!
//! `tnngen serve ... --metrics ADDR` binds this endpoint next to the
//! (framed, binary) serve front-end. It follows the same
//! spawn-detached-accept-loop shape as `serve::tcp::TcpFront`, but
//! speaks just enough HTTP that `curl`, Prometheus and a browser can
//! scrape it directly:
//!
//! * `GET /metrics.json` → the merged `tnngen.metrics/v1` JSON snapshot
//! * any other path (canonically `GET /metrics`) → Prometheus text
//!   exposition
//!
//! Responses are `Connection: close`; every scrape is one short-lived
//! connection, which keeps the responder stateless and dependency-free.

use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::coordinator::jobs::spawn_worker;
use crate::obs::metrics::{render_json_merged, render_prometheus_merged, Registry};
use crate::Result;

/// Cap on the request head we are willing to buffer.
const MAX_HEAD: usize = 4096;

/// Total time budget for reading one request head. A per-read timeout
/// alone is not enough: a peer trickling one byte per read keeps the
/// connection (and its thread) alive indefinitely. The deadline bounds
/// the WHOLE read, however the bytes arrive.
const READ_DEADLINE: Duration = Duration::from_secs(2);

/// Running metrics endpoint. The accept loop and per-connection
/// threads are detached and live until process exit.
pub struct MetricsServer {
    local_addr: SocketAddr,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `"127.0.0.1:9464"`, port 0 for ephemeral) and
    /// serve scrapes of `sources` (rendered merged, in order).
    pub fn spawn(addr: &str, sources: Vec<Arc<Registry>>) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding metrics endpoint on {addr}"))?;
        let local_addr = listener.local_addr()?;
        let sources = Arc::new(sources);
        spawn_worker("tnngen-metrics-accept", move || {
            for stream in listener.incoming() {
                match stream {
                    Ok(s) => {
                        let sources = Arc::clone(&sources);
                        spawn_worker("tnngen-metrics-conn", move || {
                            let _ = serve_conn(s, &sources);
                        });
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(MetricsServer { local_addr })
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }
}

/// Read a request head under the size cap and total deadline. `Ok(None)`
/// means the request must be rejected (oversized, truncated, or stalled
/// past the deadline).
fn read_head(stream: &mut TcpStream) -> std::io::Result<Option<Vec<u8>>> {
    let deadline = Instant::now() + READ_DEADLINE;
    let mut head = Vec::new();
    let mut buf = [0u8; 512];
    loop {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Ok(None);
        }
        stream.set_read_timeout(Some(remaining))?;
        let n = match stream.read(&mut buf) {
            Ok(n) => n,
            // A stall past the deadline surfaces as WouldBlock/TimedOut
            // depending on platform; both mean "reject", not "error".
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                return Ok(None);
            }
            Err(e) => return Err(e),
        };
        if n == 0 {
            // EOF before the terminator: a truncated request.
            return Ok(None);
        }
        head.extend_from_slice(&buf[..n]);
        if head.len() > MAX_HEAD {
            return Ok(None);
        }
        if head.windows(4).any(|w| w == b"\r\n\r\n") {
            return Ok(Some(head));
        }
    }
}

fn serve_conn(mut stream: TcpStream, sources: &[Arc<Registry>]) -> std::io::Result<()> {
    let Some(head) = read_head(&mut stream)? else {
        // Oversized, truncated, or stalled request: reject instead of
        // rendering a 200 (the pre-fix behavior served anything).
        let reply = "HTTP/1.0 400 Bad Request\r\nContent-Length: 0\r\nConnection: close\r\n\r\n";
        stream.write_all(reply.as_bytes())?;
        return stream.flush();
    };
    let request = String::from_utf8_lossy(&head);
    let path = request.split_whitespace().nth(1).unwrap_or("/metrics");
    let (content_type, body) = if path.starts_with("/metrics.json") {
        ("application/json", render_json_merged(sources).pretty())
    } else {
        ("text/plain; version=0.0.4", render_prometheus_merged(sources))
    };
    let header = format!(
        "HTTP/1.0 200 OK\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(header.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::artifacts;

    fn scrape(addr: SocketAddr, path: &str) -> (String, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.0\r\nHost: test\r\n\r\n").unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).unwrap();
        let (head, body) = raw.split_once("\r\n\r\n").expect("response has a header block");
        (head.to_string(), body.to_string())
    }

    #[test]
    fn serves_prometheus_text_and_json_snapshot() {
        let reg = Arc::new(Registry::new());
        reg.counter("t_scrape_total").add(5);
        let srv = MetricsServer::spawn("127.0.0.1:0", vec![Arc::clone(&reg)]).unwrap();

        let (head, body) = scrape(srv.local_addr(), "/metrics");
        assert!(head.starts_with("HTTP/1.0 200 OK"), "{head}");
        assert!(head.contains("text/plain"), "{head}");
        assert!(body.contains("t_scrape_total 5"), "{body}");

        reg.counter("t_scrape_total").inc();
        let (head, body) = scrape(srv.local_addr(), "/metrics.json");
        assert!(head.contains("application/json"), "{head}");
        let doc = artifacts::parse(&body).expect("JSON snapshot parses");
        assert_eq!(
            doc.get("counters").and_then(|c| c.get("t_scrape_total")).and_then(|v| v.as_i64()),
            Some(6),
            "scrape must reflect live counter state"
        );
    }

    #[test]
    fn oversized_request_head_is_rejected_with_400() {
        let srv = MetricsServer::spawn("127.0.0.1:0", vec![Arc::new(Registry::new())]).unwrap();
        let mut s = TcpStream::connect(srv.local_addr()).unwrap();
        // A request line that never ends and blows straight past the cap.
        let junk = vec![b'a'; MAX_HEAD + 512];
        s.write_all(b"GET /").unwrap();
        s.write_all(&junk).unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.0 400"), "oversized head must be rejected, got: {raw}");
    }

    #[test]
    fn stalled_or_truncated_request_is_bounded_and_rejected() {
        let srv = MetricsServer::spawn("127.0.0.1:0", vec![Arc::new(Registry::new())]).unwrap();
        let start = std::time::Instant::now();
        let mut s = TcpStream::connect(srv.local_addr()).unwrap();
        // Half a request head, then a stall: the server must give up at
        // its total deadline, not hold the connection on per-read resets.
        s.write_all(b"GET /metrics HT").unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.0 400"), "stalled head must be rejected, got: {raw}");
        let elapsed = start.elapsed();
        assert!(
            elapsed < READ_DEADLINE + Duration::from_secs(3),
            "rejection must land near the {READ_DEADLINE:?} deadline, took {elapsed:?}"
        );
    }
}
