//! Unified observability: span tracing, a metrics registry, a leveled
//! logger, and a live scrape endpoint.
//!
//! Before this module the repo measured time four disconnected ways
//! (serve counters, EDA stopwatch laps, bench iteration clocks, ad-hoc
//! prints). `obs` replaces them with three pillars that every hot
//! subsystem shares:
//!
//! * [`trace`] — RAII spans recorded into lock-free per-thread ring
//!   buffers, exported as a versioned `tnngen.trace/v1` Chrome Trace
//!   Event artifact (`--trace-out FILE`, loadable in Perfetto /
//!   `chrome://tracing`). Disabled cost is a single relaxed atomic
//!   load, so spans live permanently on the sim/serve/pool hot paths
//!   (pinned by `tests/alloc.rs`).
//! * [`metrics`] — named lock-free instruments (counters, gauges,
//!   log-linear HDR histograms) in per-service and process-global
//!   registries, rendered as Prometheus text exposition or a
//!   `tnngen.metrics/v1` JSON snapshot.
//! * [`log`] — a leveled, `TNNGEN_LOG`-controlled stderr logger so
//!   library code never prints unconditionally; plus [`scrape`], a
//!   tiny HTTP endpoint (`tnngen serve --metrics ADDR`) that serves
//!   both metrics renderings live.
//!
//! See `docs/OBSERVABILITY.md` for the span model, overhead
//! guarantees, and artifact schemas.

pub mod log;
pub mod metrics;
pub mod scrape;
pub mod trace;
