//! Span tracing: near-zero-overhead timed spans exported as a
//! versioned Chrome Trace Event artifact (`tnngen.trace/v1`).
//!
//! Design:
//!
//! * One global `AtomicBool` gates everything. While tracing is
//!   disabled (the default) [`span`] costs a single relaxed atomic
//!   load — no clock read, no allocation — so spans can sit
//!   permanently on the sim/serve/pool hot paths (`tests/alloc.rs`
//!   pins this).
//! * While enabled, each recording thread appends finished spans to
//!   its own fixed-capacity ring buffer. Appends are wait-free for the
//!   owning thread; every slot carries a seqlock-style sequence
//!   counter so [`snapshot`] (callable from any thread) discards torn
//!   reads instead of ever blocking a writer. A wrapped ring
//!   overwrites its oldest events and reports them as dropped.
//! * Span and category names are `&'static str`, so recording never
//!   copies strings; dynamic names can be leaked once via [`intern`]
//!   (call it behind an [`enabled`] check on hot paths).
//!
//! The export format is the Chrome Trace Event Format — an object with
//! a `traceEvents` array of phase-`"X"` (complete) events, timestamps
//! and durations in microseconds — loadable directly in
//! `chrome://tracing` or <https://ui.perfetto.dev>. A `schema` tag
//! versions the artifact like every other tnngen JSON document, and
//! emit → parse → emit is byte-stable (shortest-round-trip float
//! rendering, same contract as the bench artifact).

use std::cell::{RefCell, UnsafeCell};
use std::collections::BTreeSet;
use std::path::Path;
use std::sync::atomic::Ordering::{Acquire, Relaxed, Release};
use std::sync::atomic::{fence, AtomicBool, AtomicU64};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{ensure, Context};

use crate::report::artifacts::{self, Json};
use crate::Result;

/// Schema tag stamped into every exported trace artifact.
pub const TRACE_SCHEMA: &str = "tnngen.trace/v1";

/// Events kept per recording thread before the ring wraps.
const RING_SLOTS: usize = 4096;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Base instant all span timestamps are measured against; fixed by the
/// first enable so trace timestamps start near zero.
static BASE: OnceLock<Instant> = OnceLock::new();

/// True when spans are being recorded — one relaxed atomic load.
/// Callers use it to skip building dynamic span metadata.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Relaxed)
}

/// Turn recording on or off without touching already-recorded events.
/// The first enable fixes the trace's base timestamp.
pub fn set_enabled(on: bool) {
    if on {
        BASE.get_or_init(Instant::now);
    }
    ENABLED.store(on, Relaxed);
}

/// Enable recording (see [`set_enabled`]).
pub fn enable() {
    set_enabled(true);
}

/// Disable recording; recorded events stay available to [`snapshot`].
pub fn disable() {
    set_enabled(false);
}

/// A finished span as stored in the ring: plain `Copy` data so a torn
/// cross-thread read is detectable-garbage, never undefined pointers
/// (names are `'static`, so even a torn read dereferences validly —
/// the seqlock check below still discards it).
#[derive(Clone, Copy)]
struct RawEvent {
    name: &'static str,
    cat: &'static str,
    start_ns: u64,
    dur_ns: u64,
}

const EMPTY_EVENT: RawEvent = RawEvent { name: "", cat: "", start_ns: 0, dur_ns: 0 };

struct Slot {
    /// Seqlock sequence: 0 = never written, odd = write in progress.
    seq: AtomicU64,
    event: UnsafeCell<RawEvent>,
}

/// Single-producer ring buffer owned by one recording thread.
struct ThreadRing {
    /// Trace-local thread id (registration order).
    tid: u64,
    /// Monotonic count of events ever pushed by the owning thread.
    written: AtomicU64,
    slots: Vec<Slot>,
}

// SAFETY: each `event` cell is written only by the ring's owning thread
// (rings are handed out through a thread-local). Readers validate the
// per-slot `seq` counter before and after copying and discard torn
// reads, so cross-thread access never treats a partial write as valid.
unsafe impl Sync for ThreadRing {}

impl ThreadRing {
    fn new(tid: u64) -> Self {
        let slots = (0..RING_SLOTS)
            .map(|_| Slot { seq: AtomicU64::new(0), event: UnsafeCell::new(EMPTY_EVENT) })
            .collect();
        ThreadRing { tid, written: AtomicU64::new(0), slots }
    }

    /// Owning-thread-only append (wait-free; wraps over oldest events).
    fn push(&self, ev: RawEvent) {
        let i = self.written.load(Relaxed);
        let slot = &self.slots[(i as usize) % RING_SLOTS];
        let seq = slot.seq.load(Relaxed);
        // Classic seqlock write protocol: odd marks in-progress, the
        // fences order the data write between the two seq stores.
        slot.seq.store(seq.wrapping_add(1), Relaxed);
        fence(Release);
        // SAFETY: only the owning thread writes this cell (see the
        // `Sync` impl); the volatile write keeps the compiler from
        // folding it across the seq stores.
        unsafe { std::ptr::write_volatile(slot.event.get(), ev) };
        fence(Release);
        slot.seq.store(seq.wrapping_add(2), Release);
        self.written.store(i + 1, Release);
    }

    /// Copy out every valid event; returns how many were lost to
    /// wrap-around. Callable from any thread.
    fn read_into(&self, out: &mut Vec<RawEvent>) -> u64 {
        let written = self.written.load(Acquire);
        let dropped = written.saturating_sub(RING_SLOTS as u64);
        for slot in &self.slots {
            let s1 = slot.seq.load(Acquire);
            if s1 == 0 || s1 % 2 == 1 {
                continue; // never written, or mid-write
            }
            // SAFETY: a concurrent write tears at worst; the seq
            // re-check below rejects exactly that case.
            let ev = unsafe { std::ptr::read_volatile(slot.event.get()) };
            fence(Acquire);
            if slot.seq.load(Relaxed) == s1 {
                out.push(ev);
            }
        }
        dropped
    }
}

fn ring_registry() -> &'static Mutex<Vec<Arc<ThreadRing>>> {
    static RINGS: OnceLock<Mutex<Vec<Arc<ThreadRing>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static LOCAL_RING: RefCell<Option<Arc<ThreadRing>>> = const { RefCell::new(None) };
}

/// Run `f` against this thread's ring, registering (and allocating) it
/// on first use. Only called while tracing is enabled, so the one-time
/// allocation never lands on a traced-out hot path.
fn with_local_ring(f: impl FnOnce(&ThreadRing)) {
    LOCAL_RING.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            let mut all = ring_registry().lock().expect("trace ring registry poisoned");
            let ring = Arc::new(ThreadRing::new(all.len() as u64));
            all.push(Arc::clone(&ring));
            *slot = Some(ring);
        }
        if let Some(ring) = slot.as_ref() {
            f(ring);
        }
    });
}

/// Record a completed span from explicit endpoints — used where the
/// natural start lives on another thread (request queue wait) or where
/// a stage already measured its own `Instant` pair (EDA flow stages).
pub fn record_range(name: &'static str, cat: &'static str, start: Instant, end: Instant) {
    if !enabled() {
        return;
    }
    let Some(base) = BASE.get() else { return };
    let start_ns = start.saturating_duration_since(*base).as_nanos().min(u64::MAX as u128) as u64;
    let dur_ns = end.saturating_duration_since(start).as_nanos().min(u64::MAX as u128) as u64;
    with_local_ring(|ring| ring.push(RawEvent { name, cat, start_ns, dur_ns }));
}

/// RAII guard recording one complete span when dropped (see [`span`]).
#[must_use = "a span is recorded on Drop; binding it to _ drops it immediately"]
pub struct SpanGuard {
    name: &'static str,
    cat: &'static str,
    start: Option<Instant>,
}

impl Drop for SpanGuard {
    #[inline]
    fn drop(&mut self) {
        if let Some(start) = self.start {
            record_range(self.name, self.cat, start, Instant::now());
        }
    }
}

/// Open a span in the default category. The span closes — and is
/// recorded — when the returned guard drops. While tracing is disabled
/// this is one relaxed atomic load: no clock read, no allocation.
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    span_cat(name, "tnngen")
}

/// Open a span with an explicit category (subsystem name).
#[inline]
pub fn span_cat(name: &'static str, cat: &'static str) -> SpanGuard {
    let start = if enabled() { Some(Instant::now()) } else { None };
    SpanGuard { name, cat, start }
}

/// Intern a dynamic string as `&'static str` for use as a span name or
/// category. Each distinct string leaks exactly once; call this behind
/// an [`enabled`] check on hot paths.
pub fn intern(s: &str) -> &'static str {
    static INTERNED: OnceLock<Mutex<BTreeSet<&'static str>>> = OnceLock::new();
    let table = INTERNED.get_or_init(|| Mutex::new(BTreeSet::new()));
    let mut table = table.lock().expect("intern table poisoned");
    if let Some(hit) = table.get(s) {
        return hit;
    }
    let leaked: &'static str = Box::leak(s.to_owned().into_boxed_str());
    table.insert(leaked);
    leaked
}

/// One complete (phase-`"X"`) event of a Chrome Trace artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Span name (e.g. `serve.infer`).
    pub name: String,
    /// Category — the subsystem that recorded the span.
    pub cat: String,
    /// Start time in microseconds from the trace base.
    pub ts_us: f64,
    /// Duration in microseconds.
    pub dur_us: f64,
    /// Process id (always 1 for in-process traces).
    pub pid: i64,
    /// Recording thread's trace-local id.
    pub tid: i64,
}

/// Copy out every recorded span, sorted by (timestamp, thread, name)
/// for deterministic rendering, plus the number of events lost to
/// ring wrap-around across all threads. Non-destructive; best called
/// at quiescence (concurrent appends may or may not be included).
pub fn snapshot() -> (Vec<TraceEvent>, u64) {
    let rings: Vec<Arc<ThreadRing>> =
        ring_registry().lock().expect("trace ring registry poisoned").clone();
    let mut events = Vec::new();
    let mut dropped = 0u64;
    let mut raw = Vec::new();
    for ring in rings {
        raw.clear();
        dropped += ring.read_into(&mut raw);
        for ev in &raw {
            events.push(TraceEvent {
                name: ev.name.to_string(),
                cat: ev.cat.to_string(),
                ts_us: ev.start_ns as f64 / 1000.0,
                dur_us: ev.dur_ns as f64 / 1000.0,
                pid: 1,
                tid: ring.tid as i64,
            });
        }
    }
    events.sort_by(|a, b| {
        a.ts_us
            .total_cmp(&b.ts_us)
            .then(a.tid.cmp(&b.tid))
            .then_with(|| a.name.cmp(&b.name))
    });
    (events, dropped)
}

/// Render events as a `tnngen.trace/v1` Chrome Trace Event document.
pub fn trace_json(events: &[TraceEvent], dropped: u64) -> Json {
    let rows = events
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("name", Json::Str(e.name.clone())),
                ("cat", Json::Str(e.cat.clone())),
                ("ph", Json::Str("X".to_string())),
                ("ts", Json::Num(e.ts_us)),
                ("dur", Json::Num(e.dur_us)),
                ("pid", Json::Int(e.pid)),
                ("tid", Json::Int(e.tid)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::Str(TRACE_SCHEMA.to_string())),
        ("displayTimeUnit", Json::Str("ms".to_string())),
        ("droppedEvents", Json::Int(dropped.min(i64::MAX as u64) as i64)),
        ("traceEvents", Json::Arr(rows)),
    ])
}

/// Parse a `tnngen.trace/v1` document (inverse of [`trace_json`]).
/// Returns the events and the recorded dropped-event count.
pub fn parse_trace(text: &str) -> Result<(Vec<TraceEvent>, u64)> {
    let doc = artifacts::parse(text).context("parsing trace artifact")?;
    let schema = doc.get("schema").and_then(Json::as_str).unwrap_or("");
    ensure!(
        schema == TRACE_SCHEMA,
        "unsupported trace schema {schema:?} (this build reads {TRACE_SCHEMA})"
    );
    let dropped = doc.get("droppedEvents").and_then(Json::as_i64).unwrap_or(0).max(0) as u64;
    let rows = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .context("trace artifact has no traceEvents array")?;
    let mut events = Vec::with_capacity(rows.len());
    for row in rows {
        events.push(TraceEvent {
            name: row.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
            cat: row.get("cat").and_then(Json::as_str).unwrap_or("").to_string(),
            ts_us: row.get("ts").and_then(Json::as_f64).context("trace event missing ts")?,
            dur_us: row.get("dur").and_then(Json::as_f64).context("trace event missing dur")?,
            pid: row.get("pid").and_then(Json::as_i64).unwrap_or(1),
            tid: row.get("tid").and_then(Json::as_i64).unwrap_or(0),
        });
    }
    Ok((events, dropped))
}

/// Snapshot the recorded spans and write them to `path` as a Chrome
/// trace file. Returns the number of events written.
pub fn write_chrome_trace(path: &Path) -> Result<usize> {
    let (events, dropped) = snapshot();
    let doc = trace_json(&events, dropped);
    crate::util::atomic_io::write_atomic(path, doc.pretty().as_bytes())
        .with_context(|| format!("writing trace to {}", path.display()))?;
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<TraceEvent> {
        vec![
            TraceEvent {
                name: "serve.infer".to_string(),
                cat: "serve".to_string(),
                ts_us: 12.345,
                dur_us: 0.1,
                pid: 1,
                tid: 0,
            },
            TraceEvent {
                name: "pool.chunk".to_string(),
                cat: "pool".to_string(),
                ts_us: 12345678.9,
                dur_us: 4242.0,
                pid: 1,
                tid: 3,
            },
        ]
    }

    #[test]
    fn emit_parse_emit_is_byte_stable() {
        let first = trace_json(&sample_events(), 7).pretty();
        let (parsed, dropped) = parse_trace(&first).unwrap();
        assert_eq!(parsed, sample_events());
        assert_eq!(dropped, 7);
        let second = trace_json(&parsed, dropped).pretty();
        assert_eq!(first, second, "trace artifact must round-trip byte-identically");
    }

    #[test]
    fn schema_mismatch_is_rejected() {
        let doc = trace_json(&sample_events(), 0).pretty();
        let wrong = doc.replace(TRACE_SCHEMA, "tnngen.trace/v999");
        let err = parse_trace(&wrong).unwrap_err();
        assert!(format!("{err:#}").contains("unsupported trace schema"), "{err:#}");
    }

    #[test]
    fn intern_dedups_and_returns_stable_pointers() {
        let a = intern("dyn.name.a");
        let b = intern("dyn.name.a");
        assert!(std::ptr::eq(a, b), "same string must intern to the same allocation");
        assert_eq!(intern("dyn.name.b"), "dyn.name.b");
    }

    #[test]
    fn disabled_guard_records_nothing_even_if_tracing_turns_on_later() {
        // A guard opened while tracing is off holds no start instant,
        // so its Drop is inert regardless of later global state.
        let g = SpanGuard { name: "test.inert", cat: "test", start: None };
        drop(g);
        let (events, _) = snapshot();
        assert!(events.iter().all(|e| e.name != "test.inert"));
    }
}
