//! Minimal leveled stderr logger, controlled by `TNNGEN_LOG`.
//!
//! Library code must never print unconditionally: every diagnostic
//! goes through this module so users (and tests) can silence or
//! amplify it with `TNNGEN_LOG=off|error|warn|info|debug`. The default
//! threshold is `warn`, so degraded-behavior notes (e.g. the synthetic
//! UCR-data fallback in `data::`) still surface out of the box while
//! routine lifecycle chatter stays hidden.
//!
//! CLI output in `main.rs` (usage text, command results) is *not*
//! logging and intentionally bypasses this module.

use std::sync::atomic::{AtomicU8, Ordering::Relaxed};

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable or data-affecting problems.
    Error = 0,
    /// Degraded behavior the user should know about (default threshold).
    Warn = 1,
    /// High-level lifecycle events.
    Info = 2,
    /// Per-operation detail.
    Debug = 3,
}

impl Level {
    fn name(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }
}

/// Threshold meaning "emit nothing, not even errors" (`TNNGEN_LOG=off`).
const SILENT: u8 = 100;
/// Sentinel: threshold not yet resolved from the environment.
const UNSET: u8 = u8::MAX;

static THRESHOLD: AtomicU8 = AtomicU8::new(UNSET);

fn parse_level(s: &str) -> u8 {
    match s.to_ascii_lowercase().as_str() {
        "off" | "none" | "silent" => SILENT,
        "error" => Level::Error as u8,
        "warn" | "warning" => Level::Warn as u8,
        "info" => Level::Info as u8,
        "debug" | "trace" => Level::Debug as u8,
        _ => Level::Warn as u8,
    }
}

fn threshold() -> u8 {
    let t = THRESHOLD.load(Relaxed);
    if t != UNSET {
        return t;
    }
    let resolved = match std::env::var("TNNGEN_LOG") {
        Ok(v) => parse_level(&v),
        Err(_) => Level::Warn as u8,
    };
    THRESHOLD.store(resolved, Relaxed);
    resolved
}

/// Override the threshold programmatically (tests, future CLI flags);
/// `None` silences everything. Wins over `TNNGEN_LOG`.
pub fn set_level(level: Option<Level>) {
    THRESHOLD.store(level.map_or(SILENT, |l| l as u8), Relaxed);
}

/// True when events at `level` would be emitted — check this before
/// building an expensive message.
pub fn level_enabled(level: Level) -> bool {
    (level as u8) <= threshold()
}

/// Emit one event as `tnngen[LEVEL] target: message` on stderr.
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if !level_enabled(level) {
        return;
    }
    eprintln!("tnngen[{}] {target}: {args}", level.name());
}

/// Error-level event (see [`log`]).
pub fn error(target: &str, args: std::fmt::Arguments<'_>) {
    log(Level::Error, target, args);
}

/// Warn-level event (see [`log`]).
pub fn warn(target: &str, args: std::fmt::Arguments<'_>) {
    log(Level::Warn, target, args);
}

/// Info-level event (see [`log`]).
pub fn info(target: &str, args: std::fmt::Arguments<'_>) {
    log(Level::Info, target, args);
}

/// Debug-level event (see [`log`]).
pub fn debug(target: &str, args: std::fmt::Arguments<'_>) {
    log(Level::Debug, target, args);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_matches_severity() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn parse_accepts_the_documented_spellings() {
        assert_eq!(parse_level("off"), SILENT);
        assert_eq!(parse_level("ERROR"), Level::Error as u8);
        assert_eq!(parse_level("warning"), Level::Warn as u8);
        assert_eq!(parse_level("Info"), Level::Info as u8);
        assert_eq!(parse_level("debug"), Level::Debug as u8);
        assert_eq!(parse_level("garbage"), Level::Warn as u8, "unknown values mean warn");
    }
}
