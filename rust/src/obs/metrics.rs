//! Named-instrument metrics registry: lock-free counters, gauges and
//! log-linear HDR histograms.
//!
//! This generalizes what `serve::metrics` pioneered — relaxed-atomic
//! counters and a 16-sub-buckets-per-octave microsecond histogram —
//! into instruments any subsystem can register by name: serve keeps
//! its per-service registry (snapshots stay bit-identical), while the
//! worker pool and the flow cache publish into the process-global
//! [`global`] registry. A registry renders as Prometheus text
//! exposition ([`Registry::render_prometheus`]) or as a
//! `tnngen.metrics/v1` JSON snapshot, both served live by
//! [`crate::obs::scrape::MetricsServer`].
//!
//! Instrument handles are `Arc`s: subsystems resolve names once at
//! construction time and then touch only their own atomics, so the
//! registry's `Mutex` is never on a hot path.
//!
//! The histogram is HDR-style: 16 linear sub-buckets per power-of-two
//! octave of microseconds bound relative error at ~6% across the full
//! `u64` range while `record` stays three relaxed atomic adds.
//! Percentiles use the same nearest-rank definition as `util::stats`
//! and report a bucket's lower bound — a slight underestimate, never
//! an interpolated fiction. Samples landing in the unbounded top
//! bucket are additionally counted as [`Histogram::saturated`], so
//! top-bucket saturation is visible instead of silently flattening
//! the tail.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use crate::report::artifacts::Json;
use crate::util::stats::nearest_rank_index;

/// Schema tag of the JSON metrics snapshot document.
pub const METRICS_SCHEMA: &str = "tnngen.metrics/v1";

/// Linear sub-buckets per octave.
pub const SUB_BUCKETS: u64 = 16;
/// Total bucket count: values 0..16 map 1:1, then 16 buckets per octave
/// for octaves 4..=63 — covers every `u64` microsecond value.
pub const BUCKETS: usize = ((63 - 3) * SUB_BUCKETS + SUB_BUCKETS) as usize;

/// Index of the histogram bucket containing `v` (microseconds).
pub fn bucket_index(v: u64) -> usize {
    if v < SUB_BUCKETS {
        return v as usize;
    }
    let msb = 63 - u64::from(v.leading_zeros()); // >= 4
    let group = msb - 3;
    let sub = (v >> (msb - 4)) - SUB_BUCKETS; // 0..16
    ((group * SUB_BUCKETS + sub) as usize).min(BUCKETS - 1)
}

/// Smallest microsecond value that lands in bucket `idx` (the value the
/// percentile query reports for that bucket).
pub fn bucket_floor_us(idx: usize) -> u64 {
    let idx = idx as u64;
    if idx < SUB_BUCKETS {
        return idx;
    }
    let group = idx / SUB_BUCKETS;
    let sub = idx % SUB_BUCKETS;
    (sub + SUB_BUCKETS) << (group - 1)
}

/// Format an instrument name carrying one Prometheus label, e.g.
/// `labeled("tnngen_router_requests_total", "node", addr)` →
/// `tnngen_router_requests_total{node="127.0.0.1:7071"}`. Each distinct
/// label value is its own instrument in the registry; the renderer emits
/// one `# TYPE` line per base name (the part before `{`). Counters and
/// gauges only — histogram rendering appends its own labels.
pub fn labeled(name: &str, label: &str, value: &str) -> String {
    let mut escaped = String::with_capacity(value.len());
    for c in value.chars() {
        if c == '\\' || c == '"' {
            escaped.push('\\');
        }
        escaped.push(c);
    }
    format!("{name}{{{label}=\"{escaped}\"}}")
}

/// The metric name with any `{label="..."}` suffix stripped.
fn base_name(name: &str) -> &str {
    name.split('{').next().unwrap_or(name)
}

/// Monotonically increasing counter (relaxed atomic adds).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add one.
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Relaxed);
    }

    /// Add `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// Last-value / high-water instrument.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// Overwrite the value.
    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Relaxed);
    }

    /// Raise the value to at least `v` (high-water mark).
    #[inline]
    pub fn record_max(&self, v: u64) {
        self.0.fetch_max(v, Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// Lock-free log-linear histogram of microsecond values (see the
/// module docs for the bucket layout and error bound).
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    saturated: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            saturated: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    /// Record one duration sample (saturated to whole microseconds).
    pub fn record(&self, d: Duration) {
        self.record_us(d.as_micros().min(u64::MAX as u128) as u64);
    }

    /// Record one microsecond sample.
    pub fn record_us(&self, us: u64) {
        let idx = bucket_index(us);
        if idx == BUCKETS - 1 {
            // The top bucket is unbounded above: its floor no longer
            // carries the ~6% relative-error guarantee, so count these
            // samples explicitly instead of flattening them silently.
            self.saturated.fetch_add(1, Relaxed);
        }
        self.buckets[idx].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        self.sum_us.fetch_add(us, Relaxed);
    }

    /// Total samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }

    /// Sum of all recorded microsecond values.
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Relaxed)
    }

    /// Samples that landed in the unbounded top bucket.
    pub fn saturated(&self) -> u64 {
        self.saturated.load(Relaxed)
    }

    /// Mean recorded value in microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Relaxed) as f64 / n as f64
    }

    /// Nearest-rank p-th percentile in microseconds (0 when empty). The
    /// rank is resolved against cumulative bucket counts and the bucket's
    /// lower bound is reported.
    pub fn percentile_us(&self, p: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = nearest_rank_index(n as usize, p) as u64;
        let mut cum = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            cum += b.load(Relaxed);
            if cum > target {
                return bucket_floor_us(idx) as f64;
            }
        }
        bucket_floor_us(BUCKETS - 1) as f64
    }
}

#[derive(Debug)]
enum Instrument {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// Named-instrument registry. Instruments are registered get-or-create
/// by name and keep insertion order in every rendering, so output is
/// deterministic for a given registration sequence.
#[derive(Debug, Default)]
pub struct Registry {
    instruments: Mutex<Vec<(String, Instrument)>>,
}

impl Registry {
    /// Fresh empty registry.
    pub fn new() -> Self {
        Registry::default()
    }

    /// Get or create the counter `name`.
    ///
    /// # Panics
    /// Panics if `name` is already registered as a different kind.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut ins = self.instruments.lock().expect("metrics registry poisoned");
        for (n, i) in ins.iter() {
            if n == name {
                if let Instrument::Counter(c) = i {
                    return Arc::clone(c);
                }
                panic!("metric {name} is already registered with a different kind");
            }
        }
        let c = Arc::new(Counter::default());
        ins.push((name.to_string(), Instrument::Counter(Arc::clone(&c))));
        c
    }

    /// Get or create the gauge `name`.
    ///
    /// # Panics
    /// Panics if `name` is already registered as a different kind.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut ins = self.instruments.lock().expect("metrics registry poisoned");
        for (n, i) in ins.iter() {
            if n == name {
                if let Instrument::Gauge(g) = i {
                    return Arc::clone(g);
                }
                panic!("metric {name} is already registered with a different kind");
            }
        }
        let g = Arc::new(Gauge::default());
        ins.push((name.to_string(), Instrument::Gauge(Arc::clone(&g))));
        g
    }

    /// Get or create the histogram `name`.
    ///
    /// # Panics
    /// Panics if `name` is already registered as a different kind.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut ins = self.instruments.lock().expect("metrics registry poisoned");
        for (n, i) in ins.iter() {
            if n == name {
                if let Instrument::Histogram(h) = i {
                    return Arc::clone(h);
                }
                panic!("metric {name} is already registered with a different kind");
            }
        }
        let h = Arc::new(Histogram::default());
        ins.push((name.to_string(), Instrument::Histogram(Arc::clone(&h))));
        h
    }

    /// Render every instrument in Prometheus text exposition format.
    /// Histograms render as summaries (quantile labels + `_sum` +
    /// `_count`) plus a `<name>_saturated_total` counter.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        self.render_prometheus_into(&mut out);
        out
    }

    fn render_prometheus_into(&self, out: &mut String) {
        use std::fmt::Write as _;
        let ins = self.instruments.lock().expect("metrics registry poisoned");
        // One `# TYPE` line per base name: labeled series like
        // `foo{node="a"}` and `foo{node="b"}` share the type declaration
        // of `foo`. Linear scan — registries hold tens of instruments.
        let mut typed: Vec<&str> = Vec::new();
        for (name, i) in ins.iter() {
            let base = base_name(name);
            match i {
                Instrument::Counter(c) => {
                    if !typed.contains(&base) {
                        typed.push(base);
                        let _ = writeln!(out, "# TYPE {base} counter");
                    }
                    let _ = writeln!(out, "{name} {}", c.get());
                }
                Instrument::Gauge(g) => {
                    if !typed.contains(&base) {
                        typed.push(base);
                        let _ = writeln!(out, "# TYPE {base} gauge");
                    }
                    let _ = writeln!(out, "{name} {}", g.get());
                }
                Instrument::Histogram(h) => {
                    let _ = writeln!(out, "# TYPE {name} summary");
                    for (q, p) in [("0.5", 50.0), ("0.95", 95.0), ("0.99", 99.0)] {
                        let _ =
                            writeln!(out, "{name}{{quantile=\"{q}\"}} {}", h.percentile_us(p));
                    }
                    let _ = writeln!(out, "{name}_sum {}", h.sum_us());
                    let _ = writeln!(out, "{name}_count {}", h.count());
                    let _ = writeln!(out, "# TYPE {name}_saturated_total counter");
                    let _ = writeln!(out, "{name}_saturated_total {}", h.saturated());
                }
            }
        }
    }

    fn collect_json(
        &self,
        counters: &mut Vec<(String, Json)>,
        gauges: &mut Vec<(String, Json)>,
        histograms: &mut Vec<(String, Json)>,
    ) {
        let ins = self.instruments.lock().expect("metrics registry poisoned");
        for (name, i) in ins.iter() {
            match i {
                Instrument::Counter(c) => {
                    counters.push((name.clone(), Json::Int(c.get().min(i64::MAX as u64) as i64)));
                }
                Instrument::Gauge(g) => {
                    gauges.push((name.clone(), Json::Int(g.get().min(i64::MAX as u64) as i64)));
                }
                Instrument::Histogram(h) => {
                    histograms.push((
                        name.clone(),
                        Json::obj(vec![
                            ("count", Json::Int(h.count().min(i64::MAX as u64) as i64)),
                            ("sum_us", Json::Int(h.sum_us().min(i64::MAX as u64) as i64)),
                            ("saturated", Json::Int(h.saturated().min(i64::MAX as u64) as i64)),
                            ("p50_us", Json::Num(h.percentile_us(50.0))),
                            ("p95_us", Json::Num(h.percentile_us(95.0))),
                            ("p99_us", Json::Num(h.percentile_us(99.0))),
                            ("mean_us", Json::Num(h.mean_us())),
                        ]),
                    ));
                }
            }
        }
    }

    /// Render every instrument as a `tnngen.metrics/v1` JSON snapshot.
    pub fn render_json(&self) -> Json {
        let mut counters = Vec::new();
        let mut gauges = Vec::new();
        let mut histograms = Vec::new();
        self.collect_json(&mut counters, &mut gauges, &mut histograms);
        metrics_doc(counters, gauges, histograms)
    }
}

fn metrics_doc(
    counters: Vec<(String, Json)>,
    gauges: Vec<(String, Json)>,
    histograms: Vec<(String, Json)>,
) -> Json {
    Json::obj(vec![
        ("schema", Json::Str(METRICS_SCHEMA.to_string())),
        ("counters", Json::Obj(counters)),
        ("gauges", Json::Obj(gauges)),
        ("histograms", Json::Obj(histograms)),
    ])
}

/// Render several registries as one Prometheus exposition document
/// (concatenated in order; registries must not share metric names).
pub fn render_prometheus_merged(sources: &[Arc<Registry>]) -> String {
    let mut out = String::new();
    for r in sources {
        r.render_prometheus_into(&mut out);
    }
    out
}

/// Render several registries as one `tnngen.metrics/v1` JSON snapshot.
pub fn render_json_merged(sources: &[Arc<Registry>]) -> Json {
    let mut counters = Vec::new();
    let mut gauges = Vec::new();
    let mut histograms = Vec::new();
    for r in sources {
        r.collect_json(&mut counters, &mut gauges, &mut histograms);
    }
    metrics_doc(counters, gauges, histograms)
}

/// Process-wide registry for subsystems without a per-instance home
/// (the worker pool, the flow cache). Serve creates per-service
/// registries instead so concurrent services never mix counts.
pub fn global() -> Arc<Registry> {
    static GLOBAL: OnceLock<Arc<Registry>> = OnceLock::new();
    Arc::clone(GLOBAL.get_or_init(|| Arc::new(Registry::new())))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_or_create_returns_the_same_instrument() {
        let r = Registry::new();
        let a = r.counter("t_total");
        let b = r.counter("t_total");
        a.add(2);
        b.inc();
        assert_eq!(a.get(), 3);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn kind_mismatch_panics() {
        let r = Registry::new();
        let _ = r.counter("t_mixed");
        let _ = r.gauge("t_mixed");
    }

    #[test]
    fn labeled_series_share_one_type_line() {
        let r = Registry::new();
        r.counter(&labeled("t_routed_total", "node", "10.0.0.1:7071")).add(2);
        r.counter(&labeled("t_routed_total", "node", "10.0.0.2:7071")).add(3);
        let text = r.render_prometheus();
        assert_eq!(text.matches("# TYPE t_routed_total counter").count(), 1);
        assert!(text.contains("t_routed_total{node=\"10.0.0.1:7071\"} 2"));
        assert!(text.contains("t_routed_total{node=\"10.0.0.2:7071\"} 3"));
        // Quotes and backslashes in a label value are escaped.
        assert_eq!(labeled("m", "k", "a\"b\\c"), "m{k=\"a\\\"b\\\\c\"}");
    }

    #[test]
    fn gauge_high_water_only_goes_up() {
        let g = Gauge::default();
        g.record_max(5);
        g.record_max(3);
        assert_eq!(g.get(), 5);
        g.set(1);
        assert_eq!(g.get(), 1);
    }

    #[test]
    fn histogram_counts_top_bucket_saturation() {
        let h = Histogram::default();
        h.record_us(42);
        assert_eq!(h.saturated(), 0);
        h.record_us(u64::MAX);
        h.record(Duration::from_secs(u64::MAX / 1000));
        assert_eq!(h.saturated(), 2, "top-bucket samples must be counted explicitly");
        assert_eq!(h.count(), 3);
    }

    #[test]
    fn prometheus_rendering_covers_every_kind() {
        let r = Registry::new();
        r.counter("t_served_total").add(7);
        r.gauge("t_depth").set(3);
        let h = r.histogram("t_latency_us");
        h.record_us(10);
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE t_served_total counter"), "{text}");
        assert!(text.contains("t_served_total 7"), "{text}");
        assert!(text.contains("# TYPE t_depth gauge"), "{text}");
        assert!(text.contains("t_depth 3"), "{text}");
        assert!(text.contains("t_latency_us{quantile=\"0.5\"} 10"), "{text}");
        assert!(text.contains("t_latency_us_count 1"), "{text}");
        assert!(text.contains("t_latency_us_saturated_total 0"), "{text}");
    }

    #[test]
    fn json_rendering_merges_registries_with_a_schema_tag() {
        let a = Arc::new(Registry::new());
        let b = Arc::new(Registry::new());
        a.counter("t_a_total").inc();
        b.gauge("t_b_depth").set(9);
        let doc = render_json_merged(&[Arc::clone(&a), Arc::clone(&b)]);
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some(METRICS_SCHEMA));
        let counters = doc.get("counters").expect("counters section");
        assert_eq!(counters.get("t_a_total").and_then(Json::as_i64), Some(1));
        let gauges = doc.get("gauges").expect("gauges section");
        assert_eq!(gauges.get("t_b_depth").and_then(Json::as_i64), Some(9));
    }
}
