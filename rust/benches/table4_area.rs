//! Bench: regenerate Table IV (post-P&R die area) plus the §III-B
//! largest-column summary (die mm^2 / total power / latency).

mod bench_common;

use bench_common::{banner, bench_effort};
use tnngen::report::experiments::{largest_column_summary, run_paper_flows, table4};

fn main() {
    let effort = bench_effort();
    banner("Table IV — post-place-and-route die area");
    let flows = run_paper_flows(effort).expect("flows");
    println!("{}", table4(&flows, effort).unwrap());
    if let Some(s) = largest_column_summary(&flows) {
        println!("{s}");
    }
}
