//! Bench: regenerate Table V + Fig 4 (area/leakage forecasting from
//! synapse count: trained regression, predictions, per-design errors).

mod bench_common;

use bench_common::{banner, bench_effort};
use tnngen::report::experiments::{run_paper_flows, table5_fig4};

fn main() {
    let effort = bench_effort();
    banner("Table V + Fig 4 — post-P&R forecasting (TNN7)");
    let flows = run_paper_flows(effort).expect("flows");
    println!("{}", table5_fig4(&flows, effort).unwrap());
}
