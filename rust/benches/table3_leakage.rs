//! Bench: regenerate Table III (post-P&R leakage power, 7 designs x 3
//! libraries) and time a representative flow.

mod bench_common;

use bench_common::{banner, bench, bench_effort};
use tnngen::config::presets::by_tag;
use tnngen::eda::{asap7, run_flow, FlowOpts};
use tnngen::report::experiments::{run_paper_flows, table3};

fn main() {
    let effort = bench_effort();
    banner("Table III — post-place-and-route leakage power");
    let flows = run_paper_flows(effort).expect("flows");
    println!("{}", table3(&flows, effort).unwrap());

    banner("flow timing (ASAP7, 96x2)");
    let cfg = by_tag("96x2").unwrap();
    bench("full flow ASAP7 96x2", 3, || {
        let _ = run_flow(&cfg, &asap7(), &FlowOpts::default()).unwrap();
    });
}
