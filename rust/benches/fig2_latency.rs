//! Bench: regenerate Fig 2 (computation latency; three columns on a shared
//! floorplan + the largest column) with ASCII layout plots.

mod bench_common;

use bench_common::{banner, bench_effort};
use tnngen::config::presets::by_tag;
use tnngen::eda::{place, synthesize, tnn7, PlaceOpts};
use tnngen::report::experiments::{fig2, layout_ascii};
use tnngen::rtl::generate_column;

fn main() {
    let effort = bench_effort();
    banner("Fig 2 — computation latencies on a shared floorplan (TNN7)");
    println!("{}", fig2(effort).unwrap());

    banner("layouts (placement density maps, TNN7)");
    for tag in ["65x2", "96x2", "152x2"] {
        let cfg = by_tag(tag).unwrap();
        let rtl = generate_column(&cfg).unwrap();
        let d = synthesize(&rtl.netlist, &tnn7());
        let p = place(&d, &PlaceOpts::default());
        println!(
            "{tag}: {} instances on {:.0}x{:.0} um",
            d.instances.len(),
            p.die_w_um,
            p.die_h_um
        );
        println!("{}", layout_ascii(&p, 48));
    }
}
