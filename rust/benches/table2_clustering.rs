//! Bench: regenerate Table II (time-series clustering rand index) and time
//! the clustering hot path on both backends.

mod bench_common;

use bench_common::{banner, bench, bench_effort};
use tnngen::cluster::pipeline::TnnClustering;
use tnngen::config::presets::by_tag;
use tnngen::coordinator::{Coordinator, SimBackend};
use tnngen::data::load_benchmark;
use tnngen::report::experiments::table2;

fn main() {
    let effort = bench_effort();
    banner("Table II — clustering (PJRT backend when artifacts exist)");
    let (backend, coord) = match Coordinator::with_artifacts(std::path::Path::new("artifacts")) {
        Ok(c) => (SimBackend::Pjrt, c),
        Err(e) => {
            eprintln!("artifacts unavailable ({e}); falling back to native backend");
            (SimBackend::Native, Coordinator::native())
        }
    };
    match table2(effort, backend, &coord) {
        Ok(t) => println!("{t}"),
        Err(e) => {
            eprintln!("PJRT table2 failed ({e}); retrying native");
            let coord = Coordinator::native();
            println!("{}", table2(effort, SimBackend::Native, &coord).unwrap());
        }
    }

    banner("clustering hot-path timings (ECG200, 120 samples)");
    let cfg = by_tag("96x2").unwrap();
    let ds = load_benchmark(&cfg.name, cfg.p, cfg.q, 60, 42);
    let pipe = TnnClustering { epochs: 1, seed: 42, n_per_split: 60 };
    let native_coord = Coordinator::native();
    bench("native train+infer epoch (96x2)", 5, || {
        let _ = native_coord
            .run_clustering(&cfg, &ds, &pipe, SimBackend::Native)
            .unwrap();
    });
    if backend == SimBackend::Pjrt {
        bench("pjrt train+infer epoch (96x2)", 3, || {
            let _ = coord.run_clustering(&cfg, &ds, &pipe, SimBackend::Pjrt).unwrap();
        });
    }
}
