//! Bench: regenerate Fig 3 (place-and-route runtime, ASAP7 vs TNN7, vs
//! column size) and the §III-C synthesis/full-flow speedup claims. All
//! numbers are measured wall-clock of this machine's flow stages.

mod bench_common;

use bench_common::{banner, bench_effort};
use tnngen::report::experiments::fig3;

fn main() {
    let effort = bench_effort();
    banner("Fig 3 — P&R runtime: ASAP7 vs TNN7 (measured wall-clock)");
    println!("{}", fig3(effort).unwrap());
}
