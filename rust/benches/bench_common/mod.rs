//! Shared bench-harness helpers (offline substitute for criterion):
//! statistical timing plus the paper-table regeneration entry points.
//!
//! Every bench binary prints the corresponding paper table/figure rows so
//! `cargo bench | tee bench_output.txt` records the full reproduction.
// Each bench target compiles this module separately and uses a subset of
// the helpers; the unused ones in any one target are not dead code.
#![allow(dead_code)]

use tnngen::report::experiments::Effort;
use tnngen::util::stats::{mean, median, stddev};
use tnngen::util::timer::time_iters;

/// Effort selection: `TNNGEN_BENCH_FAST=1` trims to the three smallest
/// designs (useful for smoke runs); default reproduces every row.
pub fn bench_effort() -> Effort {
    if std::env::var("TNNGEN_BENCH_FAST").ok().as_deref() == Some("1") {
        Effort::fast()
    } else {
        Effort::full()
    }
}

/// Time a closure `iters` times and print a criterion-style summary line.
pub fn bench<F: FnMut()>(name: &str, iters: usize, f: F) {
    let samples = time_iters(iters, f);
    println!(
        "bench {name:<40} median {:>10.3} ms  mean {:>10.3} ms  sd {:>8.3} ms  n={}",
        median(&samples) * 1e3,
        mean(&samples) * 1e3,
        stddev(&samples) * 1e3,
        samples.len()
    );
}

pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}
