//! Bench: hot-path microbenchmarks for the §Perf optimization pass
//! (EXPERIMENTS.md). Per-layer: native response path, batched-vs-sequential
//! dataset engine, gate-level sim throughput, SA placement move rate,
//! synthesis optimization rate, and PJRT dispatch cost.

mod bench_common;

use bench_common::{banner, bench};
use tnngen::config::presets::by_tag;
use tnngen::config::ColumnConfig;
use tnngen::coordinator::explorer::{explore_with_workers, SweepSpace};
use tnngen::coordinator::jobs::default_workers;
use tnngen::coordinator::{Coordinator, SimBackend};
use tnngen::cluster::pipeline::TnnClustering;
use tnngen::data::{load_benchmark, generate};
use tnngen::eda::synthesis::{optimize, SynthStats};
use tnngen::eda::{place, synthesize, tnn7, FlowCampaign, PlaceOpts};
use tnngen::report::experiments::{run_paper_flows_with, Effort};
use tnngen::rtl::{generate_column, GateSim};
use tnngen::sim::{BatchSim, CycleSim};
use tnngen::util::stats::median;
use tnngen::util::timer::time_iters;
use tnngen::util::Rng;

/// Like `bench`, but also returns the median seconds so sections can print
/// sequential-vs-batched speedup ratios.
fn bench_median<F: FnMut()>(name: &str, iters: usize, f: F) -> f64 {
    let samples = time_iters(iters, f);
    let med = median(&samples);
    println!("bench {name:<40} median {:>10.3} ms  n={}", med * 1e3, samples.len());
    med
}

fn main() {
    banner("L3 perf: native functional simulator");
    let cfg = by_tag("96x2").unwrap();
    let mut sim = CycleSim::new(cfg.clone(), 1);
    let mut rng = Rng::new(9);
    let xs: Vec<Vec<f32>> = (0..120)
        .map(|_| (0..96).map(|_| rng.f32()).collect())
        .collect();
    bench("native step x120 (96x2)", 10, || {
        for x in &xs {
            sim.step(x);
        }
    });
    bench("native infer x120 (96x2)", 10, || {
        for x in &xs {
            let _ = sim.infer(x);
        }
    });

    banner("L3 perf: event-driven vs cycle-accurate response");
    let s_enc: Vec<Vec<i32>> = xs.iter().map(|x| sim.encode(x)).collect();
    bench("cycle-accurate response x120", 10, || {
        for s in &s_enc {
            let _ = sim.response(s);
        }
    });
    let theta = sim.config.theta();
    let params = sim.config.params;
    bench("event-driven response x120", 10, || {
        for s in &s_enc {
            let _ = tnngen::sim::event::event_driven(&sim.weights, sim.config.p, s, theta, &params);
        }
    });

    banner("L3 perf: batched vs sequential dataset engine (96x2)");
    println!("workers: {}", default_workers());
    let frozen = sim.clone();
    let batch = BatchSim::from_sim(frozen.clone());
    let t_seq = bench_median("sequential infer x120 (96x2)", 20, || {
        for x in &xs {
            let _ = frozen.infer(x);
        }
    });
    let t_bat = bench_median("batched infer x120 (96x2)", 20, || {
        let _ = batch.infer_winners(&xs);
    });
    println!("batched dataset inference speedup: {:.2}x (acceptance floor: 2x)", t_seq / t_bat);

    let sweep_cfg = by_tag("16x2").unwrap();
    let sweep_ds = generate("ECG200", 16, 2, 40, 3);
    let sweep_pipe = TnnClustering { epochs: 2, seed: 1, n_per_split: 40 };
    let space = SweepSpace::default(); // 9 points
    let cfgs = space.configs(&sweep_cfg);
    let t_sweep_seq = bench_median("sequential sweep, 9 pts (16x2)", 5, || {
        for c in &cfgs {
            let _ = sweep_pipe.run_native_sequential(c, &sweep_ds);
        }
    });
    let t_sweep_bat = bench_median("batched sweep, 9 pts (16x2)", 5, || {
        let _ = explore_with_workers(&sweep_cfg, &sweep_ds, &space, &sweep_pipe, default_workers());
    });
    println!("batched sweep speedup: {:.2}x", t_sweep_seq / t_sweep_bat);

    banner("L3 perf: serve shard pool (96x2, fixed open-loop offered load)");
    {
        use tnngen::serve::{run_open_loop, LoadSpec, ServeOpts, TnnService};
        let spec = LoadSpec {
            rps: 3000.0,
            duration_s: 1.0,
            learn_every: 0,
            drain_timeout: std::time::Duration::from_secs(5),
        };
        let mut single_p99 = 0.0;
        for shards in [1usize, default_workers()] {
            let svc = TnnService::start(cfg.clone(), 1, ServeOpts { shards, ..Default::default() });
            let r = run_open_loop(&svc, &xs, &spec);
            svc.shutdown();
            println!(
                "serve {shards:>2} shard(s): {:>6.0} rps completed (offered {:.0}), p50 {:>6.0} us  p95 {:>7.0} us  p99 {:>7.0} us, rejected {}",
                r.throughput_rps, spec.rps, r.latency_p50_us, r.latency_p95_us, r.latency_p99_us, r.rejected
            );
            if shards == 1 {
                single_p99 = r.latency_p99_us;
            } else if single_p99 > 0.0 && r.latency_p99_us > 0.0 {
                println!(
                    "serve p99 improvement 1 -> {shards} shards: {:.2}x at {:.0} rps offered",
                    single_p99 / r.latency_p99_us,
                    spec.rps
                );
            }
        }
    }

    banner("L3 perf: gate-level simulator");
    let small = ColumnConfig::new("perf", "synthetic", 12, 2);
    let rtl = generate_column(&small).unwrap();
    let mut gsim = GateSim::new(&rtl.netlist).unwrap();
    rtl.load_weights(&mut gsim, &vec![vec![28u64; 12]; 2]);
    let spikes: Vec<i32> = (0..12).map(|i| (i % 8) as i32).collect();
    bench("gate-level sample (12x2 column)", 10, || {
        let _ = rtl.run_sample(&mut gsim, &spikes, true);
    });

    banner("L3 perf: synthesis optimization + SA placement");
    let cfg_hw = by_tag("65x2").unwrap();
    let rtl_hw = generate_column(&cfg_hw).unwrap();
    bench("synthesis optimize (65x2 ASAP7 fabric)", 3, || {
        let mut stats = SynthStats::default();
        let _ = optimize(&rtl_hw.netlist, &mut stats);
    });
    let design = synthesize(&rtl_hw.netlist, &tnn7());
    bench("SA placement (65x2 TNN7)", 3, || {
        let _ = place(&design, &PlaceOpts::default());
    });

    banner("L3 perf: flow campaign (fast effort: 3 designs x 3 libraries)");
    let effort = Effort::fast();
    let t_c1 = bench_median("flow campaign, 1 worker", 2, || {
        let _ = run_paper_flows_with(effort, &FlowCampaign::with_workers(1)).unwrap();
    });
    let nw = default_workers();
    let t_cn = bench_median(&format!("flow campaign, {nw} workers"), 2, || {
        let _ = run_paper_flows_with(effort, &FlowCampaign::with_workers(nw)).unwrap();
    });
    println!(
        "flow campaign speedup: {:.2}x with {nw} workers (9 independent flows, deterministic order)",
        t_c1 / t_cn
    );
    let cache_dir = std::env::temp_dir().join(format!("tnngen_bench_cache_{}", std::process::id()));
    let warm_fill = FlowCampaign::with_workers(nw).with_cache_dir(&cache_dir).unwrap();
    let _ = run_paper_flows_with(effort, &warm_fill).unwrap();
    let t_warm = bench_median("flow campaign, warm cache", 3, || {
        let c = FlowCampaign::with_workers(nw).with_cache_dir(&cache_dir).unwrap();
        let _ = run_paper_flows_with(effort, &c).unwrap();
    });
    println!(
        "warm-cache campaign speedup vs cold 1-worker: {:.0}x (all flow stages skipped)",
        t_c1 / t_warm
    );
    std::fs::remove_dir_all(&cache_dir).ok();

    banner("L1/L2 perf: PJRT dispatch (requires artifacts)");
    if let Ok(coord) = Coordinator::with_artifacts(std::path::Path::new("artifacts")) {
        let cfg2 = by_tag("96x2").unwrap();
        let ds = load_benchmark(&cfg2.name, cfg2.p, cfg2.q, 32, 42);
        let pipe = TnnClustering { epochs: 1, seed: 42, n_per_split: 32 };
        bench("pjrt epoch 64 samples (96x2)", 3, || {
            let _ = coord.run_clustering(&cfg2, &ds, &pipe, SimBackend::Pjrt).unwrap();
        });
    } else {
        println!("artifacts not built; skipping PJRT microbench");
    }
}
