//! Bench: hot-path performance rows for `cargo bench` compatibility.
//!
//! Since the bench subsystem landed (`tnngen bench`, `rust/src/bench/`),
//! this binary is a thin Criterion-free shim over the same registry — one
//! source of truth for workload setup instead of bespoke rows. It runs
//! the full engine × workload matrix (seven paper designs on
//! cyclesim/batchsim/serve, the encode/STDP/WTA micro hot paths, and the
//! fast-effort flow campaign) and prints one row per entry.
//!
//! `TNNGEN_BENCH_FAST=1` selects the quick profile (small datasets, 3
//! iterations); the default is the full baseline-recording profile. For
//! artifacts, diffs and regression gating use the CLI:
//! `tnngen bench record` / `bench diff` / `bench check` (see
//! docs/BENCHMARKS.md).

use tnngen::bench::{default_registry, render_row, row_header, run_entry, Profile, RunnerOpts};
use tnngen::coordinator::jobs::default_workers;

fn main() {
    let profile = if std::env::var("TNNGEN_BENCH_FAST").ok().as_deref() == Some("1") {
        Profile::Quick
    } else {
        Profile::Full
    };
    let opts = RunnerOpts::for_profile(profile);
    println!(
        "perf_hotpath shim over the tnngen bench registry ({} profile, {} workers, \
         {} warmup + {} iters per entry)",
        profile.name(),
        default_workers(),
        opts.warmup_iters,
        opts.iters
    );
    println!("{}", row_header());
    for entry in default_registry(profile) {
        let result = run_entry(&entry, &opts);
        println!("{}", render_row(&result));
    }
    println!("(record/diff/gate these rows with `tnngen bench record|diff|check`)");
}
