"""Pallas kernels vs pure-jnp ref.py oracle — the CORE correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.response import TP, TQ, potentials
from compile.kernels.stdp import stdp_update
from compile.kernels.wta import wta

RNG = np.random.RandomState(1234)


def rand_inputs(q_pad, p_pad, T=8, T_R=32, seed=0):
    rng = np.random.RandomState(seed)
    W = rng.uniform(0.0, 7.0, size=(q_pad, p_pad)).astype(np.float32)
    s = rng.randint(0, T, size=(p_pad,)).astype(np.int32)
    return jnp.asarray(W), jnp.asarray(s)


@pytest.mark.parametrize("q_pad,p_pad", [(8, 128), (8, 256), (16, 128),
                                         (32, 384), (8, 640)])
@pytest.mark.parametrize("response", ["rnl", "snl", "lif"])
def test_potentials_matches_ref(q_pad, p_pad, response):
    W, s = rand_inputs(q_pad, p_pad, seed=q_pad + p_pad)
    got = potentials(W, s, T_R=32, response=response, lif_decay=0.9)
    want = ref.potentials_ref(W, s, 32, response, 0.9)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_potentials_padded_synapses_contribute_zero():
    """Spike time >= T_R (the padding sentinel) must add nothing."""
    W, s = rand_inputs(8, 256, seed=7)
    s_padded = s.at[128:].set(32)            # second tile = all padding
    W_zero_tail = W.at[:, 128:].set(0.0)
    got = potentials(W, s_padded, T_R=32)
    want = potentials(W_zero_tail, s_padded, T_R=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_potentials_rnl_monotone_in_t():
    W, s = rand_inputs(8, 128, seed=3)
    V = np.asarray(potentials(W, s, T_R=32, response="rnl"))
    assert np.all(np.diff(V, axis=1) >= -1e-5)


def test_potentials_snl_bounded_by_weight_sum():
    W, s = rand_inputs(8, 128, seed=4)
    V = np.asarray(potentials(W, s, T_R=32, response="snl"))
    assert np.all(V <= np.asarray(W).sum(axis=1, keepdims=True) + 1e-3)


@pytest.mark.parametrize("grid", [(8, 128), (16, 256), (8, 384)])
def test_stdp_matches_ref(grid):
    q_pad, p_pad = grid
    W, s = rand_inputs(q_pad, p_pad, seed=11)
    rng = np.random.RandomState(5)
    y = jnp.asarray(rng.randint(0, 33, size=(q_pad,)).astype(np.int32))
    mask = jnp.asarray((np.arange(q_pad) < q_pad - 2).astype(np.int32))
    got = stdp_update(W, s, y, mask, T=8, T_R=32, w_max=7,
                      mu_capture=1.0, mu_backoff=1.0, mu_search=0.125)
    want_full = ref.stdp_ref(W, s, y, 8, 32, 7, 1.0, 1.0, 0.125)
    want = W + (want_full - W) * mask[:, None].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_stdp_clamps_to_range():
    W = jnp.full((8, 128), 6.9, dtype=jnp.float32)
    s = jnp.zeros((128,), dtype=jnp.int32)
    y = jnp.full((8,), 5, dtype=jnp.int32)      # all capture
    mask = jnp.ones((8,), dtype=jnp.int32)
    W2 = stdp_update(W, s, y, mask, T=8, T_R=32, w_max=7,
                     mu_capture=1.0, mu_backoff=0.5, mu_search=0.1)
    assert float(jnp.max(W2)) <= 7.0
    W3 = stdp_update(jnp.zeros_like(W), s + 32, y, mask, T=8, T_R=32, w_max=7,
                     mu_capture=1.0, mu_backoff=0.5, mu_search=0.1)
    assert float(jnp.min(W3)) >= 0.0


def test_stdp_masked_rows_unchanged():
    W, s = rand_inputs(16, 128, seed=21)
    y = jnp.full((16,), 3, dtype=jnp.int32)
    mask = jnp.zeros((16,), dtype=jnp.int32)
    W2 = stdp_update(W, s, y, mask, T=8, T_R=32, w_max=7,
                     mu_capture=1.0, mu_backoff=1.0, mu_search=0.125)
    np.testing.assert_array_equal(np.asarray(W2), np.asarray(W))


@pytest.mark.parametrize("tie", ["low", "high"])
def test_wta_matches_ref(tie):
    for seed in range(20):
        rng = np.random.RandomState(seed)
        q = int(rng.choice([8, 16, 32]))
        y = jnp.asarray(rng.randint(0, 33, size=(q,)).astype(np.int32))
        winner, gated = wta(y, T_R=32, tie=tie)
        w_ref, g_ref = ref.wta_ref(y, 32, tie)
        assert int(winner[0]) == int(w_ref), (seed, y)
        np.testing.assert_array_equal(np.asarray(gated), np.asarray(g_ref))


def test_wta_tie_break_low():
    y = jnp.asarray([5, 3, 3, 9, 3, 32, 32, 32], dtype=jnp.int32)
    winner, gated = wta(y, T_R=32, tie="low")
    assert int(winner[0]) == 1
    assert np.asarray(gated).tolist() == [32, 3, 32, 32, 32, 32, 32, 32]


def test_wta_tie_break_high():
    y = jnp.asarray([5, 3, 3, 9, 3, 32, 32, 32], dtype=jnp.int32)
    winner, _ = wta(y, T_R=32, tie="high")
    assert int(winner[0]) == 4


def test_wta_no_fire_reports_minus_one():
    y = jnp.full((8,), 32, dtype=jnp.int32)
    winner, gated = wta(y, T_R=32, tie="low")
    assert int(winner[0]) == -1
    assert np.all(np.asarray(gated) == 32)


def test_first_crossing_sentinel():
    V = jnp.zeros((4, 32), dtype=jnp.float32)
    y = ref.first_crossing(V, 1.0, 32)
    assert np.all(np.asarray(y) == 32)


def test_first_crossing_exact_threshold_counts():
    V = jnp.broadcast_to(jnp.arange(32, dtype=jnp.float32), (2, 32))
    y = ref.first_crossing(V, 5.0, 32)
    assert np.asarray(y).tolist() == [5, 5]


def test_tile_constants_are_mxu_aligned():
    assert TP == 128 and TQ % 8 == 0
