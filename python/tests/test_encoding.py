"""Temporal encoding tests."""

import jax.numpy as jnp
import numpy as np

from compile.encoding import encode_spike_times, minmax_normalize, pad_spike_times


def test_spike_times_in_window():
    x = jnp.asarray(np.random.RandomState(0).randn(50).astype(np.float32))
    s = np.asarray(encode_spike_times(x, 8))
    assert s.min() >= 0 and s.max() <= 7
    assert s.dtype == np.int32


def test_larger_value_spikes_earlier():
    x = jnp.asarray([0.0, 0.25, 0.5, 0.75, 1.0], dtype=jnp.float32)
    s = np.asarray(encode_spike_times(x, 8))
    assert list(s) == sorted(s, reverse=True)
    assert s[-1] == 0 and s[0] == 7


def test_extremes_map_to_window_edges():
    x = jnp.asarray([3.0, -1.0], dtype=jnp.float32)
    s = np.asarray(encode_spike_times(x, 8))
    assert s[0] == 0 and s[1] == 7


def test_constant_window_does_not_nan():
    x = jnp.ones((10,), dtype=jnp.float32) * 4.2
    s = np.asarray(encode_spike_times(x, 8))
    assert np.all((0 <= s) & (s <= 7))


def test_minmax_normalize_range():
    x = jnp.asarray(np.random.RandomState(1).randn(100).astype(np.float32))
    xh = np.asarray(minmax_normalize(x))
    assert abs(xh.min()) < 1e-6 and abs(xh.max() - 1.0) < 1e-6


def test_pad_spike_times_sentinel():
    s = jnp.asarray([1, 2, 3], dtype=jnp.int32)
    sp = np.asarray(pad_spike_times(s, 8, 32))
    assert sp.tolist() == [1, 2, 3, 32, 32, 32, 32, 32]


def test_encoding_invariant_to_affine_scale():
    """Min-max normalization makes encoding invariant to a*x + b (a > 0)."""
    x = jnp.asarray(np.random.RandomState(2).rand(30).astype(np.float32))
    s1 = np.asarray(encode_spike_times(x, 8))
    s2 = np.asarray(encode_spike_times(3.5 * x + 11.0, 8))
    np.testing.assert_array_equal(s1, s2)
