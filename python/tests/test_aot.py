"""AOT pipeline tests: lowering produces valid HLO text + a sane manifest."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.configs import (ALL_CONFIGS, INFER_BATCH, PAPER_CONFIGS,
                             TRAIN_CHUNK, by_tag, pad_to)

CFG = by_tag("16x2")


def test_pad_to():
    assert pad_to(65, 128) == 128
    assert pad_to(128, 128) == 128
    assert pad_to(129, 128) == 256
    assert pad_to(270, 128) == 384


def test_paper_configs_match_table2():
    got = [(c.name, c.p, c.q) for c in PAPER_CONFIGS]
    assert got == [
        ("SonyAIBORobotSurface2", 65, 2),
        ("ECG200", 96, 2),
        ("Wafer", 152, 2),
        ("ToeSegmentation2", 343, 2),
        ("Lightning2", 637, 2),
        ("Beef", 470, 5),
        ("WordSynonyms", 270, 25),
    ]
    # Synapse counts as in Tables III/IV.
    assert [c.synapse_count for c in PAPER_CONFIGS] == \
        [130, 192, 304, 686, 1274, 2350, 6750]


def test_lower_config_produces_hlo_text():
    arts = list(aot.lower_config(CFG))
    names = [n for n, _, _ in arts]
    assert names == [f"tnn_step_{CFG.tag}", f"tnn_infer_{CFG.tag}",
                     f"tnn_infer_batch_{CFG.tag}", f"tnn_train_chunk_{CFG.tag}"]
    for _, text, _ in arts:
        assert "ENTRY" in text and "ROOT" in text
        # Text interchange only: serialized protos break xla_extension 0.5.1.
        assert text.lstrip().startswith("HloModule")


def test_hlo_shapes_embed_padded_dims():
    arts = {n: t for n, t, _ in aot.lower_config(CFG)}
    step = arts[f"tnn_step_{CFG.tag}"]
    assert f"f32[{CFG.q_pad},{CFG.p_pad}]" in step
    chunk = arts[f"tnn_train_chunk_{CFG.tag}"]
    assert f"f32[{TRAIN_CHUNK},{CFG.p}]" in chunk
    batch = arts[f"tnn_infer_batch_{CFG.tag}"]
    assert f"f32[{INFER_BATCH},{CFG.p}]" in batch


def test_manifest_entry_round_trips_params():
    entry = aot.manifest_entry(CFG, f"tnn_step_{CFG.tag}", "step")
    for key in ("p = 16", "q = 2", "p_pad = 128", "q_pad = 8",
                'kind = "step"', "theta =", "mu_capture = 1.0"):
        assert key in entry, key


def test_generated_artifacts_exist_and_match_manifest():
    """`make artifacts` output (if present) is complete and in sync."""
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(art_dir, "manifest.toml")
    if not os.path.exists(manifest):
        pytest.skip("artifacts not built yet (run `make artifacts`)")
    text = open(manifest).read()
    for cfg in ALL_CONFIGS:
        for base in ("tnn_step", "tnn_infer", "tnn_infer_batch",
                     "tnn_train_chunk"):
            name = f"{base}_{cfg.tag}"
            assert f"[{name}]" in text, f"{name} missing from manifest"
            assert os.path.exists(os.path.join(art_dir, f"{name}.hlo.txt"))


def test_lowered_step_executes_like_model():
    """Execute the lowered HLO via jax and compare with direct model call —
    the same cross-check the Rust integration tests perform via PJRT."""
    W = model.init_weights(CFG, 0)
    x = jnp.asarray(np.random.RandomState(3).rand(CFG.p).astype(np.float32))
    fn = jax.jit(lambda W, x: model.tnn_step(CFG, W, x))
    direct = fn(W, x)
    lowered = fn.lower(W, x)
    compiled = lowered.compile()
    via_hlo = compiled(W, x)
    for a, b in zip(jax.tree_util.tree_leaves(direct),
                    jax.tree_util.tree_leaves(via_hlo)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
