"""L2 model tests: exported computations vs pure-jnp references, invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import ALL_CONFIGS, TEST_CONFIGS, ColumnConfig, TnnParams

CFG = TEST_CONFIGS[0]           # 16x2
CFG2 = TEST_CONFIGS[1]          # 48x4


def rand_window(p, seed=0):
    return jnp.asarray(np.random.RandomState(seed).rand(p).astype(np.float32))


@pytest.mark.parametrize("cfg", TEST_CONFIGS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_step_matches_ref(cfg, seed):
    W = model.init_weights(cfg, seed)
    x = rand_window(cfg.p, seed)
    W2, winner, y = model.tnn_step(cfg, W, x)
    W2r, wr, yr = model.tnn_step_ref(cfg, W, x)
    assert int(winner[0]) == int(wr[0])
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    np.testing.assert_allclose(np.asarray(W2), np.asarray(W2r),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("cfg", TEST_CONFIGS)
def test_infer_matches_ref(cfg):
    W = model.init_weights(cfg, 3)
    x = rand_window(cfg.p, 9)
    winner, y = model.tnn_infer(cfg, W, x)
    wr, yr = model.tnn_infer_ref(cfg, W, x)
    assert int(winner[0]) == int(wr[0])
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


def test_infer_batch_consistent_with_single():
    W = model.init_weights(CFG, 1)
    X = jnp.stack([rand_window(CFG.p, s) for s in range(6)])
    batch = model.tnn_infer_batch(CFG, W, X)
    singles = [int(model.tnn_infer(CFG, W, X[i])[0][0]) for i in range(6)]
    assert np.asarray(batch).tolist() == singles


def test_train_chunk_equals_sequential_steps():
    W = model.init_weights(CFG2, 2)
    X = jnp.stack([rand_window(CFG2.p, 100 + s) for s in range(5)])
    Wc = model.tnn_train_chunk(CFG2, W, X)
    Ws = W
    for i in range(5):
        Ws, _, _ = model.tnn_step(CFG2, Ws, X[i])
    np.testing.assert_allclose(np.asarray(Wc), np.asarray(Ws),
                               rtol=1e-6, atol=1e-6)


def test_padded_rows_stay_zero_through_training():
    W = model.init_weights(CFG, 0)
    assert np.all(np.asarray(W)[CFG.q:] == 0.0)
    X = jnp.stack([rand_window(CFG.p, s) for s in range(20)])
    W2 = model.tnn_train_chunk(CFG, W, X)
    assert np.all(np.asarray(W2)[CFG.q:] == 0.0), \
        "padding neurons must never learn"


def test_padded_cols_stay_zero_through_training():
    W = model.init_weights(CFG, 0)
    X = jnp.stack([rand_window(CFG.p, s) for s in range(20)])
    W2 = model.tnn_train_chunk(CFG, W, X)
    assert np.all(np.asarray(W2)[:, CFG.p:] == 0.0), \
        "padding synapses must never learn"


def test_weights_bounded_through_training():
    W = model.init_weights(CFG2, 5)
    X = jnp.stack([rand_window(CFG2.p, s) for s in range(32)])
    W2 = model.tnn_train_chunk(CFG2, W, X)
    arr = np.asarray(W2)
    assert arr.min() >= 0.0 and arr.max() <= CFG2.params.w_max


def test_winner_in_valid_range():
    for seed in range(10):
        W = model.init_weights(CFG2, seed)
        x = rand_window(CFG2.p, seed)
        winner, _ = model.tnn_infer(CFG2, W, x)
        assert -1 <= int(winner[0]) < CFG2.q


def test_learning_specializes_neurons():
    """After STDP on two well-separated prototypes, the column should map
    them to different neurons (the basic clustering mechanism of ref [2])."""
    cfg = CFG
    rng = np.random.RandomState(0)
    proto_a = np.sin(np.linspace(0, 3 * np.pi, cfg.p))
    proto_b = np.concatenate([np.ones(cfg.p // 2), np.zeros(cfg.p - cfg.p // 2)])
    X = []
    for i in range(40):
        base = proto_a if i % 2 == 0 else proto_b
        X.append(base + rng.randn(cfg.p) * 0.05)
    X = jnp.asarray(np.asarray(X, dtype=np.float32))
    W = model.init_weights(cfg, 7)
    for start in range(0, 40, 8):
        W = model.tnn_train_chunk(cfg, W, X[start:start + 8])
    wa, _ = model.tnn_infer(cfg, W, X[0])
    wb, _ = model.tnn_infer(cfg, W, X[1])
    assert int(wa[0]) != int(wb[0]), "prototypes should map to distinct neurons"


def test_multilayer_shapes_and_range():
    l1 = ColumnConfig("L1", "synthetic", 16, 8)
    l2 = ColumnConfig("L2", "synthetic", 8, 2)
    Ws = [model.init_weights(l1, 0), model.init_weights(l2, 1)]
    winner, y = model.multilayer_infer([l1, l2], Ws, rand_window(16, 0))
    assert y.shape == (l2.q_pad,)
    assert -1 <= int(winner[0]) < l2.q


@pytest.mark.parametrize("response", ["snl", "rnl", "lif"])
def test_all_response_functions_run(response):
    cfg = ColumnConfig("R", "synthetic", 16, 2,
                       TnnParams(response=response, theta_frac=0.1))
    W = model.init_weights(cfg, 0)
    W2, winner, y = model.tnn_step(cfg, W, rand_window(16, 3))
    assert W2.shape == W.shape and y.shape == (cfg.q_pad,)


def test_supervised_step_teaches_labeled_neuron():
    """Supervised STDP (paper §II-A) forces the labeled neuron to win."""
    cfg = CFG2  # 48x4
    rng = np.random.RandomState(3)
    xa = jnp.asarray(np.sin(np.linspace(0, 3 * np.pi, cfg.p)).astype(np.float32))
    xb = jnp.asarray(
        np.concatenate([np.ones(cfg.p // 2), np.zeros(cfg.p - cfg.p // 2)])
        .astype(np.float32))
    W = model.init_weights(cfg, 5)
    for _ in range(30):
        W, _, _ = model.tnn_step_supervised(cfg, W, xa, 1)
        W, _, _ = model.tnn_step_supervised(cfg, W, xb, 3)
    wa, _ = model.tnn_infer(cfg, W, xa)
    wb, _ = model.tnn_infer(cfg, W, xb)
    assert int(wa[0]) == 1
    assert int(wb[0]) == 3
    del rng


def test_supervised_step_keeps_padding_and_bounds():
    cfg = CFG
    W = model.init_weights(cfg, 2)
    x = rand_window(cfg.p, 8)
    W2, _, _ = model.tnn_step_supervised(cfg, W, x, 0)
    arr = np.asarray(W2)
    assert arr.min() >= 0.0 and arr.max() <= cfg.params.w_max
    assert np.all(arr[cfg.q:] == 0.0)


def test_paper_configs_padding_invariants():
    for cfg in ALL_CONFIGS:
        assert cfg.p_pad % 128 == 0 and cfg.q_pad % 8 == 0
        assert cfg.p_pad >= cfg.p and cfg.q_pad >= cfg.q
        assert cfg.p_pad - cfg.p < 128 and cfg.q_pad - cfg.q < 8
