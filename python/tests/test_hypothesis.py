"""Hypothesis sweeps: Pallas kernels vs ref.py across shapes/values/params."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.response import potentials
from compile.kernels.stdp import stdp_update
from compile.kernels.wta import wta

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def padded_column(draw):
    q_tiles = draw(st.integers(1, 4))
    p_tiles = draw(st.integers(1, 5))
    q_pad, p_pad = 8 * q_tiles, 128 * p_tiles
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.RandomState(seed)
    W = rng.uniform(0.0, 7.0, size=(q_pad, p_pad)).astype(np.float32)
    # Mix in-window spikes, late spikes and the padding sentinel.
    s = rng.choice([0, 1, 3, 5, 7, 12, 32],
                   size=(p_pad,)).astype(np.int32)
    return jnp.asarray(W), jnp.asarray(s)


@given(padded_column(), st.sampled_from(["rnl", "snl", "lif"]),
       st.sampled_from([0.5, 0.8, 0.9, 0.99]))
@settings(**SETTINGS)
def test_potentials_sweep(col, response, decay):
    W, s = col
    got = potentials(W, s, T_R=32, response=response, lif_decay=decay)
    want = ref.potentials_ref(W, s, 32, response, decay)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@given(padded_column(), st.integers(0, 2**31 - 1),
       st.floats(0.01, 2.0), st.floats(0.01, 2.0), st.floats(0.0, 0.5))
@settings(**SETTINGS)
def test_stdp_sweep(col, seed, mu_c, mu_b, mu_s):
    W, s = col
    q_pad = W.shape[0]
    rng = np.random.RandomState(seed)
    y = jnp.asarray(rng.randint(0, 33, size=(q_pad,)).astype(np.int32))
    mask = jnp.asarray(rng.randint(0, 2, size=(q_pad,)).astype(np.int32))
    got = stdp_update(W, s, y, mask, T=8, T_R=32, w_max=7,
                      mu_capture=mu_c, mu_backoff=mu_b, mu_search=mu_s)
    full = ref.stdp_ref(W, s, y, 8, 32, 7, mu_c, mu_b, mu_s)
    want = W + (full - W) * mask[:, None].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.min(got)) >= 0.0 and float(jnp.max(got)) <= 7.0


@given(st.lists(st.integers(0, 32), min_size=8, max_size=32),
       st.sampled_from(["low", "high"]))
@settings(**SETTINGS)
def test_wta_sweep(times, tie):
    # Pad to a multiple of 8 with the no-spike sentinel.
    while len(times) % 8:
        times.append(32)
    y = jnp.asarray(np.asarray(times, dtype=np.int32))
    winner, gated = wta(y, T_R=32, tie=tie)
    w_ref, g_ref = ref.wta_ref(y, 32, tie)
    assert int(winner[0]) == int(w_ref)
    np.testing.assert_array_equal(np.asarray(gated), np.asarray(g_ref))
    # Invariant: at most one surviving spike after inhibition.
    assert int(np.sum(np.asarray(gated) < 32)) <= 1
