"""Temporal (intensity-to-latency) encoding of time-series windows.

Larger signal value -> earlier spike, following the direct encoding used by the
TNNGen functional simulator (paper §II-A; clustering method of ref [2]).
"""

import jax.numpy as jnp


def minmax_normalize(x: jnp.ndarray, eps: float = 1e-9) -> jnp.ndarray:
    """Per-window min-max normalization to [0, 1]."""
    lo = jnp.min(x)
    hi = jnp.max(x)
    return (x - lo) / jnp.maximum(hi - lo, eps)


def encode_spike_times(x: jnp.ndarray, T: int, T_R: int = 32,
                       cutoff: float = 0.0) -> jnp.ndarray:
    """Encode a window into integer spike times: [0, T-1] or T_R (no spike).

    x: [p] float window. s_i = round((1 - x_hat_i) * (T-1)); inputs whose
    normalized value falls below `cutoff` produce NO spike (T_R sentinel) —
    the sparse on-cell code of ref [2]. Sparsity gives the STDP
    search/backoff rules their discriminative power.
    """
    xh = minmax_normalize(x)
    s = jnp.round((1.0 - xh) * (T - 1)).astype(jnp.int32)
    return jnp.where(xh < cutoff, jnp.int32(T_R), s)


def pad_spike_times(s: jnp.ndarray, p_pad: int, T_R: int) -> jnp.ndarray:
    """Pad spike times to p_pad with the 'never spikes in-window' sentinel T_R.

    Padding with T_R makes padded synapses contribute exactly zero to every
    response function (step/ramp/LIF all evaluate to 0 for t - s < 0, and the
    response window stops at T_R - 1 < T_R).
    """
    pad = jnp.full((p_pad - s.shape[0],), T_R, dtype=jnp.int32)
    return jnp.concatenate([s, pad])
