"""Column configurations and TNN hyper-parameters (shared L1/L2 contract).

The seven (p, q) column configurations mirror Table II of the TNNGen paper:
p = synapses per neuron (== UCR series length), q = neurons (== #classes).
The same constants are mirrored on the Rust side in `rust/src/config/presets.rs`;
`python/tests/test_aot.py` checks the generated manifest keeps them in sync.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class TnnParams:
    """Hyper-parameters of a single-column TNN (paper §II-A, refs [2],[7])."""

    # Temporal encoding resolution: spike times live in [0, T). 3-bit per [7].
    T: int = 8
    # Response window: output spike times live in [0, T_R]; T_R == "no spike".
    T_R: int = 32
    # 3-bit synaptic weights per the [7] microarchitecture.
    w_max: int = 7
    # Threshold as a fraction of p * w_max (resolved per-config by `theta`).
    # Tuned by the calibration sweep recorded in EXPERIMENTS.md §TableII-tuning.
    theta_frac: float = 0.2
    # Expected-value STDP step sizes (deterministic form of [7]'s stochastic
    # rules). All three are exact in 3 fractional bits so the fixed-point
    # gate-level RTL (scale 1/8) reproduces the f32 simulator bit-for-bit.
    mu_capture: float = 1.0
    mu_backoff: float = 1.0
    mu_search: float = 0.125
    # Sparse-encoding cutoff: normalized inputs below this do not spike
    # (the on-cell code of ref [2]); 0.0 = dense. Sparsity is what lets the
    # STDP search/backoff rules discriminate cluster templates.
    sparse_cutoff: float = 0.6
    # Response function: "rnl" (ramp-no-leak), "snl" (step-no-leak), "lif".
    response: str = "rnl"
    # LIF decay factor per time unit (only used when response == "lif").
    lif_decay: float = 0.9
    # WTA tie-breaking: "low" (lowest index) or "high".
    wta_tie: str = "low"

    def theta(self, p: int) -> float:
        """Firing threshold for a column with p synapses per neuron."""
        return max(1.0, self.theta_frac * p * self.w_max)


@dataclass(frozen=True)
class ColumnConfig:
    """One (p, q) column design targeted at a UCR benchmark/modality."""

    name: str          # UCR benchmark name
    modality: str      # sensory modality (Table II)
    p: int             # synapses per neuron == series length
    q: int             # neurons == clusters
    params: TnnParams = field(default_factory=TnnParams)

    @property
    def synapse_count(self) -> int:
        return self.p * self.q

    @property
    def tag(self) -> str:
        return f"{self.p}x{self.q}"

    @property
    def p_pad(self) -> int:
        """p padded to the MXU lane multiple (128) for the Pallas matmul."""
        return pad_to(self.p, 128)

    @property
    def q_pad(self) -> int:
        """q padded to the sublane multiple (8)."""
        return pad_to(self.q, 8)

    def to_dict(self) -> dict:
        d = asdict(self)
        d["p_pad"], d["q_pad"] = self.p_pad, self.q_pad
        d["synapse_count"] = self.synapse_count
        d["theta"] = self.params.theta(self.p)
        return d


def pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


# Training-chunk length for the scan-based `tnn_train_chunk` artifact.
TRAIN_CHUNK = 32
# Batch size of the `tnn_infer_batch` artifact.
INFER_BATCH = 64

# Table II of the paper: seven representative UCR column designs.
PAPER_CONFIGS = [
    ColumnConfig("SonyAIBORobotSurface2", "Accelerometer", 65, 2),
    ColumnConfig("ECG200", "ECG", 96, 2),
    ColumnConfig("Wafer", "Fabrication process", 152, 2),
    ColumnConfig("ToeSegmentation2", "Motion sensor", 343, 2),
    ColumnConfig("Lightning2", "Optical + RF sensor", 637, 2),
    ColumnConfig("Beef", "Food spectrograph", 470, 5),
    ColumnConfig("WordSynonyms", "1D word outlines", 270, 25),
]

# Small configs for tests and the quickstart example.
TEST_CONFIGS = [
    ColumnConfig("TinyTest", "synthetic", 16, 2),
    ColumnConfig("SmallTest", "synthetic", 48, 4),
]

ALL_CONFIGS = TEST_CONFIGS + PAPER_CONFIGS


def by_tag(tag: str) -> ColumnConfig:
    for c in ALL_CONFIGS:
        if c.tag == tag:
            return c
    raise KeyError(f"no column config with tag {tag}")
