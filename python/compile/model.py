"""L2: the TNN column model in JAX, calling the L1 Pallas kernels.

This is the build-time model that `aot.py` lowers to HLO text; the Rust
coordinator executes the lowered artifacts via PJRT and never imports Python.

Exported computations (per column config):
  tnn_infer        (W, x)  -> (winner, y_times)
  tnn_step         (W, x)  -> (W', winner, y_times)      one online STDP step
  tnn_infer_batch  (W, X)  -> winners[B]                 vmapped inference
  tnn_train_chunk  (W, X)  -> W'                         lax.scan of B steps

A multi-layer simulator (`multilayer_infer`) mirrors the paper's §II-A claim
that the functional simulator supports arbitrary layer/column stacking; it is
exercised by pytest but not AOT-exported (the paper's evaluation is all
single-column).
"""

import jax
import jax.numpy as jnp

from .configs import ColumnConfig
from .encoding import encode_spike_times, pad_spike_times
from .kernels import ref
from .kernels.response import potentials
from .kernels.stdp import stdp_update
from .kernels.wta import wta


def row_mask(cfg: ColumnConfig) -> jnp.ndarray:
    """[q_pad] int32 mask: 1 for real neurons, 0 for padding."""
    idx = jnp.arange(cfg.q_pad, dtype=jnp.int32)
    return (idx < cfg.q).astype(jnp.int32)


def col_mask(cfg: ColumnConfig) -> jnp.ndarray:
    """[p_pad] int32 mask: 1 for real synapses, 0 for padding."""
    idx = jnp.arange(cfg.p_pad, dtype=jnp.int32)
    return (idx < cfg.p).astype(jnp.int32)


def init_weights(cfg: ColumnConfig, seed: int = 0) -> jnp.ndarray:
    """Initial padded weights: w_max/2 + jitter for real cells, 0 for padding.

    The jitter breaks the WTA symmetry between identically-initialized
    neurons; without it every sample would be captured by neuron 0. Padded
    rows AND columns must start at exactly zero (the STDP rules then keep
    them at zero — see the padding-invariant tests).
    """
    key = jax.random.PRNGKey(seed)
    w0 = cfg.params.w_max / 2.0
    jitter = jax.random.uniform(key, (cfg.q_pad, cfg.p_pad),
                                minval=-0.5, maxval=0.5)
    W = (w0 + jitter) * row_mask(cfg)[:, None] * col_mask(cfg)[None, :]
    return W.astype(jnp.float32)


def encode(cfg: ColumnConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Raw window x[p] -> padded spike times [p_pad]."""
    s = encode_spike_times(x, cfg.params.T, cfg.params.T_R,
                           cfg.params.sparse_cutoff)
    return pad_spike_times(s, cfg.p_pad, cfg.params.T_R)


def response(cfg: ColumnConfig, W: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """Output spike times y[q_pad] via the Pallas potentials kernel."""
    pr = cfg.params
    V = potentials(W, s, T_R=pr.T_R, response=pr.response,
                   lif_decay=pr.lif_decay)
    return ref.first_crossing(V, pr.theta(cfg.p), pr.T_R)


def tnn_infer(cfg: ColumnConfig, W: jnp.ndarray, x: jnp.ndarray):
    """(winner [1] i32, y_times [q_pad] i32) for one window."""
    pr = cfg.params
    s = encode(cfg, x)
    y = response(cfg, W, s)
    winner, _ = wta(y, T_R=pr.T_R, tie=pr.wta_tie)
    return winner, y


def tnn_step(cfg: ColumnConfig, W: jnp.ndarray, x: jnp.ndarray):
    """One online learning step: infer + WTA-gated STDP update."""
    pr = cfg.params
    s = encode(cfg, x)
    y = response(cfg, W, s)
    winner, gated = wta(y, T_R=pr.T_R, tie=pr.wta_tie)
    W2 = stdp_update(W, s, gated, row_mask(cfg),
                     T=pr.T, T_R=pr.T_R, w_max=pr.w_max,
                     mu_capture=pr.mu_capture, mu_backoff=pr.mu_backoff,
                     mu_search=pr.mu_search)
    return W2, winner, y


def tnn_infer_batch(cfg: ColumnConfig, W: jnp.ndarray, X: jnp.ndarray):
    """winners[B] i32 for a batch of windows X[B, p] (shared weights)."""
    def one(x):
        winner, _ = tnn_infer(cfg, W, x)
        return winner[0]
    return jax.vmap(one)(X)


def tnn_train_chunk(cfg: ColumnConfig, W: jnp.ndarray, X: jnp.ndarray):
    """Sequential online STDP over a chunk X[B, p]; returns updated weights.

    lax.scan keeps the chunk a single XLA dispatch — the L2 optimization that
    removes per-sample host round-trips from the Rust training loop.
    """
    def step(W, x):
        W2, _, _ = tnn_step(cfg, W, x)
        return W2, jnp.int32(0)
    W2, _ = jax.lax.scan(step, W, X)
    return W2


def tnn_step_supervised(cfg: ColumnConfig, W: jnp.ndarray, x: jnp.ndarray,
                        label: int):
    """One SUPERVISED STDP step (paper §II-A: supervised & unsupervised).

    Teacher forcing, mirroring `CycleSim::step_supervised` in Rust: the
    labeled neuron is treated as the firing output (capture); wrongly firing
    neurons get a gated time of -1 so all their in-spiking synapses back
    off; silent non-labeled neurons are untouched.
    """
    pr = cfg.params
    s = encode(cfg, x)
    y = response(cfg, W, s)
    winner, _ = wta(y, T_R=pr.T_R, tie=pr.wta_tie)
    idx = jnp.arange(cfg.q_pad, dtype=jnp.int32)
    is_label = idx == label
    fired = y < pr.T_R
    gated = jnp.where(
        is_label,
        jnp.minimum(y, pr.T_R - 1),
        jnp.where(fired & (idx < cfg.q), jnp.int32(-1), jnp.int32(pr.T_R)),
    )
    W2 = stdp_update(W, s, gated, row_mask(cfg),
                     T=pr.T, T_R=pr.T_R, w_max=pr.w_max,
                     mu_capture=pr.mu_capture, mu_backoff=pr.mu_backoff,
                     mu_search=pr.mu_search)
    return W2, winner, y


# ---------------------------------------------------------------------------
# Multi-layer simulator support (paper §II-A: arbitrary layers/columns).
# ---------------------------------------------------------------------------

def multilayer_infer(cfgs, Ws, x):
    """Stack of columns: layer k's output spike times feed layer k+1.

    cfgs: list of ColumnConfig with cfgs[k+1].p == cfgs[k].q.
    Layer outputs (spike times, early = strong) are converted back to an
    intensity vector for the next layer's encoder. Returns the last layer's
    (winner, y_times).
    """
    h = x
    winner, y = None, None
    for cfg, W in zip(cfgs, Ws):
        winner, y = tnn_infer(cfg, W, h)
        h = (cfg.params.T_R - y[: cfg.q].astype(jnp.float32)) / cfg.params.T_R
    return winner, y


# ---------------------------------------------------------------------------
# Reference (pure-jnp) versions of the exported computations, for pytest.
# ---------------------------------------------------------------------------

def tnn_step_ref(cfg: ColumnConfig, W, x):
    pr = cfg.params
    s = encode(cfg, x)
    y = ref.output_times_ref(W, s, pr.theta(cfg.p), pr.T_R,
                             pr.response, pr.lif_decay)
    winner, gated = ref.wta_ref(y, pr.T_R, pr.wta_tie)
    mask = row_mask(cfg)[:, None].astype(jnp.float32)
    W_upd = ref.stdp_ref(W, s, gated, pr.T, pr.T_R, pr.w_max,
                         pr.mu_capture, pr.mu_backoff, pr.mu_search)
    W2 = W + (W_upd - W) * mask
    return W2, jnp.reshape(winner, (1,)), y


def tnn_infer_ref(cfg: ColumnConfig, W, x):
    pr = cfg.params
    s = encode(cfg, x)
    y = ref.output_times_ref(W, s, pr.theta(cfg.p), pr.T_R,
                             pr.response, pr.lif_decay)
    winner, _ = ref.wta_ref(y, pr.T_R, pr.wta_tie)
    return jnp.reshape(winner, (1,)), y
