"""L1 Pallas kernel: TNN response potentials as an MXU-tiled matmul.

Hardware adaptation (paper targets PyTorch/CUDA; we target TPU):
the TNN response computation is re-cast as `V[q, T_R] = W[q, p] @ S[p, T_R]`,
where the response basis S is *built inside the kernel* from the int32 spike
times (one VMEM tile at a time) instead of being materialized in HBM — the
fusion a CUDA implementation would express with shared-memory staging.

Grid: (q_tiles, p_tiles); the p dimension is the contraction, accumulated
in-place into the output block (revisited across the p grid axis). Block
shapes: W tile [TQ, TP], spike tile [TP], output tile [TQ, T_R]. TP = 128
matches the MXU lane width; TQ = 8 the f32 sublane multiple. T_R = 32 keeps
the whole output block resident in VMEM.

VMEM footprint per grid step (f32): TQ*TP + TP*T_R + TQ*T_R floats
= 8*128 + 128*32 + 8*32 = 5.4 KiB -> far below the ~16 MiB VMEM budget; the
design leaves headroom to raise TQ/TP for larger columns (see DESIGN §Perf).

Pallas runs with interpret=True (CPU PJRT cannot execute Mosaic custom-calls);
the BlockSpec structure is what a real-TPU build would compile unchanged.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TQ = 8     # q-tile (sublane multiple, f32)
TP = 128   # p-tile (MXU lane width)


def _basis_tile(s_tile: jnp.ndarray, T_R: int, response: str,
                lif_decay: float) -> jnp.ndarray:
    """Build the [TP, T_R] response-basis tile from an int32 spike-time tile."""
    t = jax.lax.broadcasted_iota(jnp.float32, (s_tile.shape[0], T_R), 1)
    d = t - s_tile.astype(jnp.float32)[:, None]
    on = (d >= 0.0).astype(jnp.float32)
    if response == "snl":
        return on
    if response == "rnl":
        return on * d
    if response == "lif":
        return on * jnp.power(lif_decay, jnp.maximum(d, 0.0))
    raise ValueError(f"unknown response function {response!r}")


def _potentials_kernel(w_ref, s_ref, o_ref, *, T_R, response, lif_decay):
    ip = pl.program_id(1)

    @pl.when(ip == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    S = _basis_tile(s_ref[...], T_R, response, lif_decay)      # [TP, T_R]
    o_ref[...] += jnp.dot(w_ref[...], S,
                          preferred_element_type=jnp.float32)  # [TQ, T_R]


@functools.partial(jax.jit, static_argnames=("T_R", "response", "lif_decay"))
def potentials(W: jnp.ndarray, s: jnp.ndarray, *, T_R: int = 32,
               response: str = "rnl", lif_decay: float = 0.9) -> jnp.ndarray:
    """Membrane potentials V[q_pad, T_R] for padded W[q_pad, p_pad], s[p_pad].

    Padded synapses must carry spike time >= T_R (contribute zero); padded
    neurons must carry zero weights. `encoding.pad_spike_times` and
    `model.init_weights` maintain both invariants.
    """
    q_pad, p_pad = W.shape
    assert q_pad % TQ == 0 and p_pad % TP == 0, (q_pad, p_pad)
    assert s.shape == (p_pad,) and s.dtype == jnp.int32
    grid = (q_pad // TQ, p_pad // TP)
    kernel = functools.partial(_potentials_kernel, T_R=T_R,
                               response=response, lif_decay=lif_decay)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TQ, TP), lambda iq, ip: (iq, ip)),   # W tile
            pl.BlockSpec((TP,), lambda iq, ip: (ip,)),         # spike tile
        ],
        out_specs=pl.BlockSpec((TQ, T_R), lambda iq, ip: (iq, 0)),
        out_shape=jax.ShapeDtypeStruct((q_pad, T_R), jnp.float32),
        interpret=True,
    )(W, s)
