"""Pure-jnp oracle for the Pallas kernels (the CORE correctness reference).

Everything here is straight-line jax.numpy with no Pallas, kept deliberately
simple: the Pallas kernels in `response.py` / `stdp.py` / `wta.py` must match
these functions bit-for-bit (f32) on all shapes. The Rust native simulator
(`rust/src/sim/`) and the gate-level RTL simulator implement the same contract.
"""

import jax.numpy as jnp


def response_basis(s: jnp.ndarray, T_R: int, response: str = "rnl",
                   lif_decay: float = 0.9) -> jnp.ndarray:
    """Response basis S[p, T_R] from spike times s[p] (int32).

    snl: S[i,t] = 1                 if t >= s_i else 0   (step-no-leak)
    rnl: S[i,t] = t - s_i           if t >= s_i else 0   (ramp-no-leak)
    lif: S[i,t] = decay^(t - s_i)   if t >= s_i else 0   (leaky integrate & fire)
    """
    t = jnp.arange(T_R, dtype=jnp.float32)[None, :]          # [1, T_R]
    d = t - s.astype(jnp.float32)[:, None]                    # [p, T_R]
    on = (d >= 0.0).astype(jnp.float32)
    if response == "snl":
        return on
    if response == "rnl":
        return on * d
    if response == "lif":
        return on * jnp.power(lif_decay, jnp.maximum(d, 0.0))
    raise ValueError(f"unknown response function {response!r}")


def potentials_ref(W: jnp.ndarray, s: jnp.ndarray, T_R: int,
                   response: str = "rnl", lif_decay: float = 0.9) -> jnp.ndarray:
    """Membrane potentials V[q, T_R] = W[q, p] @ S[p, T_R]."""
    S = response_basis(s, T_R, response, lif_decay)
    return W @ S


def first_crossing(V: jnp.ndarray, theta: float, T_R: int) -> jnp.ndarray:
    """Output spike times y[q]: first t with V[j, t] >= theta, else T_R.

    Works for non-monotone potentials (LIF) as well: argmax of the boolean
    crossing mask returns the first True.
    """
    crossed = V >= theta                                      # [q, T_R]
    any_cross = jnp.any(crossed, axis=1)
    first = jnp.argmax(crossed, axis=1).astype(jnp.int32)
    return jnp.where(any_cross, first, jnp.int32(T_R))


def output_times_ref(W, s, theta, T_R, response="rnl", lif_decay=0.9):
    """Full response path: spike times in -> output spike times out."""
    V = potentials_ref(W, s, T_R, response, lif_decay)
    return first_crossing(V, theta, T_R)


def wta_ref(y: jnp.ndarray, T_R: int, tie: str = "low"):
    """1-winner-take-all over output spike times y[q].

    Returns (winner, gated) where winner is the arg-min spike time (int32, -1
    when no neuron fired) and gated[q] is the inhibited output spike vector:
    the winner keeps its spike time, all other neurons are set to T_R.
    """
    if tie == "high":
        # argmin with highest-index tie-break: argmin over reversed array.
        q = y.shape[0]
        rev = y[::-1]
        winner = (q - 1 - jnp.argmin(rev)).astype(jnp.int32)
    else:
        winner = jnp.argmin(y).astype(jnp.int32)
    fired = y[winner] < T_R
    winner = jnp.where(fired, winner, jnp.int32(-1))
    idx = jnp.arange(y.shape[0], dtype=jnp.int32)
    gated = jnp.where((idx == winner) & fired, y, jnp.int32(T_R))
    return winner, gated


def stdp_ref(W, s, y_gated, T, T_R, w_max,
             mu_capture, mu_backoff, mu_search):
    """Unsupervised expected-value STDP (deterministic form of [7]'s rules).

    W:        [q, p] weights in [0, w_max]
    s:        [p]    input spike times (int32; >= T means "no input spike")
    y_gated:  [q]    WTA-gated output spike times (T_R means "no output spike")

    Rules per synapse (i -> j):
      in & out & s_i <= y_j : w += mu_capture           (capture)
      in & out & s_i >  y_j : w -= mu_backoff           (back-off)
      in & !out             : w += mu_search            (search)
      !in & out             : w -= mu_backoff
    Result clamped to [0, w_max].
    """
    s_in = s[None, :].astype(jnp.int32)                       # [1, p]
    y_out = y_gated[:, None].astype(jnp.int32)                # [q, 1]
    has_in = s_in < T
    has_out = y_out < T_R
    capture = has_in & has_out & (s_in <= y_out)
    backoff = (has_in & has_out & (s_in > y_out)) | (~has_in & has_out)
    search = has_in & ~has_out
    dw = (capture * mu_capture - backoff * mu_backoff + search * mu_search)
    return jnp.clip(W + dw, 0.0, float(w_max)).astype(jnp.float32)
