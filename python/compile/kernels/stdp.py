"""L1 Pallas kernel: expected-value STDP weight update (elementwise, VPU).

Grid: (q_tiles, p_tiles) over the padded [q_pad, p_pad] weight matrix; each
step updates one [TQ, TP] VMEM tile. The WTA-gated output spike times y[q]
and the row-activity mask (1 for real neurons, 0 for padding) ride along as
[TQ]-blocks; spike times as [TP]-blocks. Purely elementwise -> VPU-bound;
VMEM per step = 2*TQ*TP + TP + 2*TQ floats ~= 8.6 KiB.

The row mask keeps padded neurons dead: without it the `search` rule
(in-spike & no-out-spike -> w += mu_search) would slowly grow padding weights
until a phantom neuron wins the WTA.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .response import TQ, TP


def _stdp_kernel(w_ref, s_ref, y_ref, m_ref, o_ref, *,
                 T, T_R, w_max, mu_capture, mu_backoff, mu_search):
    w = w_ref[...]                                    # [TQ, TP]
    s = s_ref[...][None, :]                           # [1, TP] int32
    y = y_ref[...][:, None]                           # [TQ, 1] int32
    mask = m_ref[...][:, None].astype(jnp.float32)    # [TQ, 1]
    has_in = s < T
    has_out = y < T_R
    capture = has_in & has_out & (s <= y)
    backoff = (has_in & has_out & (s > y)) | (~has_in & has_out)
    search = has_in & ~has_out
    dw = (capture * mu_capture - backoff * mu_backoff + search * mu_search)
    o_ref[...] = jnp.clip(w + dw * mask, 0.0, float(w_max)).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=(
    "T", "T_R", "w_max", "mu_capture", "mu_backoff", "mu_search"))
def stdp_update(W, s, y_gated, row_mask, *, T, T_R, w_max,
                mu_capture, mu_backoff, mu_search):
    """One STDP step on padded weights.

    W        [q_pad, p_pad] f32, s [p_pad] i32, y_gated [q_pad] i32,
    row_mask [q_pad] i32 (1 = real neuron, 0 = padding).
    """
    q_pad, p_pad = W.shape
    assert q_pad % TQ == 0 and p_pad % TP == 0
    grid = (q_pad // TQ, p_pad // TP)
    kernel = functools.partial(
        _stdp_kernel, T=T, T_R=T_R, w_max=w_max, mu_capture=mu_capture,
        mu_backoff=mu_backoff, mu_search=mu_search)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TQ, TP), lambda iq, ip: (iq, ip)),   # W
            pl.BlockSpec((TP,), lambda iq, ip: (ip,)),         # s
            pl.BlockSpec((TQ,), lambda iq, ip: (iq,)),         # y_gated
            pl.BlockSpec((TQ,), lambda iq, ip: (iq,)),         # row mask
        ],
        out_specs=pl.BlockSpec((TQ, TP), lambda iq, ip: (iq, ip)),
        out_shape=jax.ShapeDtypeStruct((q_pad, p_pad), jnp.float32),
        interpret=True,
    )(W, s, y_gated, row_mask)
