"""L1 Pallas kernel: 1-winner-take-all over output spike times.

q is tiny (<= 32 padded) so the whole vector fits one VMEM block; the kernel
computes the arg-min with lowest-index tie-break plus the WTA-inhibited
("gated") output spike vector in a single pass. Padded neurons never fire
(zero weights -> y = T_R) so they cannot win against any real firing neuron;
when *nothing* fires the winner is reported as -1.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wta_kernel(y_ref, w_ref, g_ref, *, T_R, tie):
    y = y_ref[...]                                     # [q_pad] i32
    q = y.shape[0]
    idx = jax.lax.broadcasted_iota(jnp.int32, (q,), 0)
    # Lexicographic key: spike time first, then index (low or high tie-break).
    tie_key = idx if tie == "low" else (q - 1 - idx)
    # Key fits comfortably in int32: y <= T_R (32) and q <= 32.
    key = y * q + tie_key
    best = jnp.min(key)
    winner = (best % q) if tie == "low" else (q - 1 - best % q)
    winner = winner.astype(jnp.int32)
    fired = (best // q) < T_R
    winner = jnp.where(fired, winner, jnp.int32(-1))
    w_ref[0] = winner
    g_ref[...] = jnp.where((idx == winner) & fired, y, jnp.int32(T_R))


@functools.partial(jax.jit, static_argnames=("T_R", "tie"))
def wta(y: jnp.ndarray, *, T_R: int = 32, tie: str = "low"):
    """Returns (winner [1] i32, gated [q_pad] i32)."""
    (q_pad,) = y.shape
    kernel = functools.partial(_wta_kernel, T_R=T_R, tie=tie)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((q_pad,), jnp.int32),
        ),
        interpret=True,
    )(y)
