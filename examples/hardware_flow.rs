//! Domain example: the hardware-generation path in isolation — generate
//! RTL for one Table-II design, cross-validate it gate-level against the
//! functional simulator, then push it through all three library flows and
//! print a silicon summary plus the layout density map.
//!
//! Run: `cargo run --release --example hardware_flow [tag]`

use tnngen::config::presets::by_tag;
use tnngen::data::generate;
use tnngen::eda::{all_libraries, place, run_flow, synthesize, tnn7, FlowOpts, PlaceOpts};
use tnngen::report::experiments::layout_ascii;
use tnngen::report::{f1, f2, Table};
use tnngen::rtl::{generate_column, GateSim};
use tnngen::sim::CycleSim;

fn main() -> anyhow::Result<()> {
    let tag = std::env::args().nth(1).unwrap_or_else(|| "65x2".to_string());
    let cfg = by_tag(&tag).ok_or_else(|| anyhow::anyhow!("unknown tag {tag}"))?;
    println!("hardware flow for {} ({}, {} synapses)\n", cfg.name, tag, cfg.synapse_count());

    // --- RTL generation + gate-level cross-validation ----------------------
    let rtl = generate_column(&cfg)?;
    println!(
        "generated RTL: {} gates, {} flops",
        rtl.netlist.gates.len(),
        rtl.netlist.num_flops()
    );
    // Validate 5 samples gate-level vs the functional simulator (Xcelium's
    // role in the paper's flow).
    let small = tnngen::config::ColumnConfig::new("xcheck", "synthetic", 10.min(cfg.p), cfg.q.min(4));
    let small_rtl = generate_column(&small)?;
    let mut gsim = GateSim::new(&small_rtl.netlist).unwrap();
    let w_fp: Vec<Vec<u64>> = (0..small.q)
        .map(|j| (0..small.p).map(|i| ((j * 13 + i * 7) % 57) as u64).collect())
        .collect();
    small_rtl.load_weights(&mut gsim, &w_fp);
    let fsim = CycleSim::from_weights(
        small.clone(),
        w_fp.iter().map(|r| r.iter().map(|&u| u as f32 / 8.0).collect()).collect(),
    );
    let ds = generate("ECG200", small.p, small.q, 5, 3);
    for (i, x) in ds.train.iter().enumerate() {
        let s = fsim.encode(x);
        let want = fsim.infer(x);
        let (gw, gy) = small_rtl.run_sample(&mut gsim, &s, false);
        assert_eq!((gw, &gy), (want.winner, &want.y), "RTL sim mismatch at {i}");
    }
    println!("gate-level RTL simulation matches the functional simulator (5/5 samples)\n");

    // --- flows across libraries ---------------------------------------------
    let mut t = Table::new(&[
        "Library", "die (um2)", "leakage (uW)", "total (mW)", "fmax (MHz)", "latency (ns)",
        "instances", "P&R (s)",
    ]);
    for lib in all_libraries() {
        let r = run_flow(&cfg, &lib, &FlowOpts::default())?;
        t.row(&[
            r.library.clone(),
            f1(r.die_area_um2),
            format!("{:.3}", r.leakage_uw),
            format!("{:.4}", r.power.total_mw()),
            f1(r.timing.fmax_mhz),
            f2(r.latency_ns),
            r.instances.to_string(),
            f2(r.runtimes.pnr_s()),
        ]);
    }
    print!("{}", t.render());

    // --- layout --------------------------------------------------------------
    let d = synthesize(&rtl.netlist, &tnn7());
    let p = place(&d, &PlaceOpts::default());
    println!(
        "\nTNN7 layout ({} instances on {:.0}x{:.0} um):",
        d.instances.len(),
        p.die_w_um,
        p.die_h_um
    );
    println!("{}", layout_ascii(&p, 56));
    Ok(())
}
