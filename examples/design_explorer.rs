//! Domain example: design-space exploration (paper §II-A — "swift design
//! space exploration ... to develop optimized TNN models").
//!
//! Sweeps the TNN hyper-parameter space for one benchmark with the fast
//! native simulator (in parallel), ranks by clustering quality, then runs
//! the hardware flow for the best point to show its silicon cost.
//!
//! Run: `cargo run --release --example design_explorer [benchmark]`

use tnngen::cluster::pipeline::TnnClustering;
use tnngen::config::presets::paper_configs;
use tnngen::coordinator::explorer::{explore, SweepSpace};
use tnngen::data::load_benchmark;
use tnngen::eda::{run_flow, tnn7, FlowOpts};
use tnngen::report::{f2, f3, Table};

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "ECG200".to_string());
    let base = paper_configs()
        .into_iter()
        .find(|c| c.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown benchmark {name}"))?;
    let pipe = TnnClustering { epochs: 4, seed: 42, n_per_split: 40 };
    let ds = load_benchmark(&base.name, base.p, base.q, pipe.n_per_split, pipe.seed);

    let space = SweepSpace {
        theta_frac: vec![0.15, 0.2, 0.3, 0.4],
        sparse_cutoff: vec![0.5, 0.6, 0.65, 0.7],
        ..Default::default()
    };
    println!(
        "exploring {} points for {} ({})...",
        space.configs(&base).len(),
        base.name,
        base.tag()
    );
    let points = explore(&base, &ds, &space, &pipe);

    let mut t = Table::new(&["rank", "theta_frac", "cutoff", "RI TNN", "RI/kmeans", "no-fire"]);
    for (i, p) in points.iter().take(10).enumerate() {
        t.row(&[
            (i + 1).to_string(),
            f2(p.config.params.theta_frac as f64),
            f2(p.config.params.sparse_cutoff as f64),
            f3(p.report.ri_tnn),
            f3(p.report.tnn_norm),
            f3(p.report.no_fire_frac),
        ]);
    }
    print!("{}", t.render());

    let best = &points[0];
    println!("\nrunning the TNN7 hardware flow for the best configuration...");
    let flow = run_flow(&best.config, &tnn7(), &FlowOpts::default())?;
    println!(
        "best point silicon cost: {:.1} um2 die, {:.3} uW leakage, {:.1} ns latency",
        flow.die_area_um2, flow.leakage_uw, flow.latency_ns
    );
    Ok(())
}
