//! Quickstart: the full TNNGen loop on one small design.
//!
//! 1. simulate a TNN column on synthetic ECG data (PJRT artifacts if built,
//!    native otherwise) and report clustering quality;
//! 2. generate its RTL;
//! 3. run the hardware flow on TNN7 and print the silicon metrics;
//! 4. forecast the metrics of a larger design without running the flow.
//!
//! Run: `cargo run --release --example quickstart`

use tnngen::cluster::pipeline::TnnClustering;
use tnngen::config::ColumnConfig;
use tnngen::coordinator::{Coordinator, SimBackend};
use tnngen::data::load_benchmark;
use tnngen::eda::{run_flow, tnn7, FlowOpts};
use tnngen::forecast::Forecaster;
use tnngen::rtl::generate_column;

fn main() -> anyhow::Result<()> {
    // A small column: 48 synapses/neuron, 4 neurons (clusters).
    let cfg = ColumnConfig::new("SmallTest", "synthetic", 48, 4);
    println!("design: {} ({} synapses)\n", cfg.tag(), cfg.synapse_count());

    // --- 1. functional simulation + clustering ---------------------------
    let (backend, coord) = match Coordinator::with_artifacts("artifacts".as_ref()) {
        Ok(c) => {
            println!("using PJRT artifacts (JAX/Pallas request path)");
            (SimBackend::Pjrt, c)
        }
        Err(_) => {
            println!("artifacts not built; using the native simulator");
            (SimBackend::Native, Coordinator::native())
        }
    };
    let pipe = TnnClustering { epochs: 4, seed: 42, n_per_split: 40 };
    let ds = load_benchmark("Beef", cfg.p, cfg.q, pipe.n_per_split, pipe.seed);
    let r = coord.run_clustering(&cfg, &ds, &pipe, backend)?;
    println!(
        "clustering: RI(TNN) = {:.3}, RI(k-means) = {:.3}, normalized = {:.3}\n",
        r.ri_tnn, r.ri_kmeans, r.tnn_norm
    );

    // --- 2. RTL generation ------------------------------------------------
    let rtl = generate_column(&cfg)?;
    println!(
        "rtl: {} gates, {} flops (structural Verilog via `tnngen generate-rtl {}`)\n",
        rtl.netlist.gates.len(),
        rtl.netlist.num_flops(),
        cfg.tag()
    );

    // --- 3. hardware flow on TNN7 ------------------------------------------
    let flow = run_flow(&cfg, &tnn7(), &FlowOpts::default())?;
    println!(
        "flow (TNN7): {:.1} um2 die, {:.3} uW leakage, {:.1} ns latency, fmax {:.0} MHz",
        flow.die_area_um2, flow.leakage_uw, flow.latency_ns, flow.timing.fmax_mhz
    );
    println!(
        "flow runtimes: synth {:.2}s + P&R {:.2}s\n",
        flow.runtimes.synthesis_s,
        flow.runtimes.pnr_s()
    );

    // --- 4. forecasting ------------------------------------------------------
    let sweep = [(16usize, 2usize), (32, 2), (48, 2), (64, 2), (48, 4)];
    let native = Coordinator::native();
    let fc: Forecaster = native.train_forecaster(&sweep, &tnn7(), &FlowOpts::default())?;
    let big = fc.predict(6750);
    println!(
        "forecast for a 6750-synapse column (no EDA run): {:.0} um2, {:.1} uW leakage",
        big.area_um2, big.leakage_uw
    );
    println!(
        "fit: Area = {:.3}*syn + {:.1}  (paper: 5.56*syn - 94.9)",
        fc.area_fit.0, fc.area_fit.1
    );
    Ok(())
}
