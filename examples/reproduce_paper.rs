//! End-to-end driver: reproduce every table and figure of the paper in one
//! run and record the outcome (the EXPERIMENTS.md source of truth).
//!
//! Exercises all three layers on a real small workload: the L1 Pallas
//! kernels + L2 JAX model execute through the PJRT artifacts for Table II
//! (falling back to the native simulator when artifacts are absent), and
//! the L3 hardware generator/EDA substrate regenerates Tables III-V and
//! Figs 2-4.
//!
//! Run: `cargo run --release --example reproduce_paper [--fast]`

use std::time::Instant;

use tnngen::coordinator::{Coordinator, SimBackend};
use tnngen::report::experiments::{
    fig2, fig3, largest_column_summary, run_paper_flows, table2, table3, table4, table5_fig4,
    Effort,
};

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let effort = if fast { Effort::fast() } else { Effort::full() };
    let t0 = Instant::now();
    let mut log = String::new();
    let mut emit = |s: &str| {
        println!("{s}");
        log.push_str(s);
        log.push('\n');
    };

    emit(&format!(
        "TNNGen reproduction run ({} mode)\n",
        if fast { "fast" } else { "full" }
    ));

    // Table II via the PJRT request path when artifacts exist.
    let (backend, coord) = match Coordinator::with_artifacts("artifacts".as_ref()) {
        Ok(c) => (SimBackend::Pjrt, c),
        Err(e) => {
            emit(&format!("(artifacts unavailable: {e}; Table II uses the native backend)"));
            (SimBackend::Native, Coordinator::native())
        }
    };
    emit(&table2(effort, backend, &coord)?);

    // Hardware tables share one set of flow runs.
    let flows = run_paper_flows(effort)?;
    emit(&table3(&flows, effort)?);
    emit(&table4(&flows, effort)?);
    if let Some(s) = largest_column_summary(&flows) {
        emit(&s);
    }
    emit(&fig2(effort)?);
    emit(&fig3(effort)?);
    emit(&table5_fig4(&flows, effort)?);

    emit(&format!("total wall-clock: {:.1} s", t0.elapsed().as_secs_f64()));
    let path = tnngen::report::save_report("reproduce_paper.txt", &log)?;
    println!("\nfull log saved to {}", path.display());
    Ok(())
}
