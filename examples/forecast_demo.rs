//! Domain example: the forecasting feature (paper §III-D) — predict
//! post-layout silicon metrics for arbitrary column sizes WITHOUT running
//! the EDA flow, after a one-time training sweep.
//!
//! Run: `cargo run --release --example forecast_demo`

use tnngen::config::presets::{paper_configs, PAPER_AREA_FIT, PAPER_LEAK_FIT};
use tnngen::coordinator::Coordinator;
use tnngen::eda::{run_flow, tnn7, FlowOpts};
use tnngen::report::experiments::forecast_sweep;
use tnngen::report::{f2, pct, Table};

fn main() -> anyhow::Result<()> {
    let coord = Coordinator::native();
    println!("training the forecaster on a sweep of TNN7 flow runs...");
    let fc = coord.train_forecaster(&forecast_sweep(false), &tnn7(), &FlowOpts::default())?;
    println!(
        "fit: Area = {:.3}*syn + {:.2} (R2 {:.4})   [paper: {}*syn + {}]",
        fc.area_fit.0, fc.area_fit.1, fc.area_fit.2, PAPER_AREA_FIT.0, PAPER_AREA_FIT.1
    );
    println!(
        "fit: Leak = {:.5}*syn + {:.4} (R2 {:.4})  [paper: {}*syn + {}]\n",
        fc.leak_fit.0, fc.leak_fit.1, fc.leak_fit.2, PAPER_LEAK_FIT.0, PAPER_LEAK_FIT.1
    );

    // Validate the forecast against an actual flow for two paper designs.
    let mut t = Table::new(&[
        "Design", "syn", "FC area", "actual", "err", "FC leak (uW)", "actual", "err",
    ]);
    for cfg in paper_configs() {
        if ![130usize, 304].contains(&cfg.synapse_count()) {
            continue;
        }
        let actual = run_flow(&cfg, &tnn7(), &FlowOpts::default())?;
        let f = fc.predict(cfg.synapse_count());
        let (ae, le) = fc.errors(&actual);
        t.row(&[
            cfg.name.clone(),
            cfg.synapse_count().to_string(),
            f2(f.area_um2),
            f2(actual.die_area_um2),
            pct(ae),
            format!("{:.3}", f.leakage_uw),
            format!("{:.3}", actual.leakage_uw),
            pct(le),
        ]);
    }
    print!("{}", t.render());

    println!("\ninstant forecasts (no EDA run):");
    for syn in [500usize, 2000, 6750, 20000] {
        let f = fc.predict(syn);
        println!(
            "  {syn:>6} synapses -> {:>10.1} um2 ({:.4} mm2), {:>8.2} uW leakage",
            f.area_um2,
            f.area_um2 / 1e6,
            f.leakage_uw
        );
    }
    Ok(())
}
