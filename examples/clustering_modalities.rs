//! Domain example: online time-series clustering across the seven sensory
//! modalities of Table II, with per-modality diagnostics (assignment
//! distribution, extended metrics) — the workload the paper's introduction
//! motivates for edge NSPUs.
//!
//! Run: `cargo run --release --example clustering_modalities [--pjrt]`

use tnngen::cluster::pipeline::TnnClustering;
use tnngen::config::presets::paper_configs;
use tnngen::coordinator::{Coordinator, SimBackend};
use tnngen::data::load_benchmark;
use tnngen::report::{f3, Table};

fn main() -> anyhow::Result<()> {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");
    let (backend, coord) = if use_pjrt {
        (
            SimBackend::Pjrt,
            Coordinator::with_artifacts("artifacts".as_ref())?,
        )
    } else {
        (SimBackend::Native, Coordinator::native())
    };
    let pipe = TnnClustering { epochs: 4, seed: 42, n_per_split: 60 };

    let mut t = Table::new(&[
        "Benchmark", "Modality", "pxq", "RI TNN", "RI kmeans", "RI DTCR*", "ARI", "NMI",
        "purity", "no-fire",
    ]);
    for cfg in paper_configs() {
        let ds = load_benchmark(&cfg.name, cfg.p, cfg.q, pipe.n_per_split, pipe.seed);
        let r = coord.run_clustering(&cfg, &ds, &pipe, backend)?;
        t.row(&[
            r.benchmark.clone(),
            r.modality.clone(),
            cfg.tag(),
            f3(r.ri_tnn),
            f3(r.ri_kmeans),
            f3(r.ri_dtcr),
            f3(r.ari_tnn),
            f3(r.nmi_tnn),
            f3(r.purity_tnn),
            format!("{:.0}%", 100.0 * r.no_fire_frac),
        ]);
        eprintln!("done: {} ({})", r.benchmark, cfg.tag());
    }
    println!("\nOnline unsupervised clustering across sensory modalities (backend {:?}):", backend);
    print!("{}", t.render());
    Ok(())
}
